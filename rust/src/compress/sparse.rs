//! Sparse codec: k values per row as f32 + ⌈log2 d⌉-bit packed indices.
//!
//! Used by Topk / RandTopk (forward: values + indices; backward: values
//! only — the feature owner already holds the indices, paper §3.1) and by
//! size reduction (neither pass sends indices: they are always 0..k).

use anyhow::{bail, Result};

use crate::util::{extend_f32s_le, index_bits, read_f32s_le_into, BitPacker, BitReader};

use super::codec::scratch_sparse;
use super::{Batch, Codec, Pass, Payload, PayloadMeta, SizeModel, SparseBatch};

/// Wire layout: per row, k f32 LE values; then (forward only) all rows'
/// indices bit-packed at ⌈log2 d⌉ bits each, padded to a byte boundary.
#[derive(Clone, Copy, Debug)]
pub struct SparseCodec {
    pub dim: usize,
    pub k: usize,
    /// Size reduction never sends indices; top-k sends them forward.
    pub send_indices: bool,
}

impl SparseCodec {
    pub fn topk(dim: usize, k: usize) -> Self {
        SparseCodec { dim, k, send_indices: true }
    }

    pub fn size_reduction(dim: usize, k: usize) -> Self {
        SparseCodec { dim, k, send_indices: false }
    }

    fn with_indices(&self, pass: Pass) -> bool {
        self.send_indices && pass == Pass::Forward
    }

    /// Exact content length: values, plus the packed index section when
    /// indices travel on this pass.
    fn content_bytes(&self, rows: usize, pass: Pass) -> usize {
        let vals = rows * self.k * 4;
        if self.with_indices(pass) {
            vals + (rows * self.k * index_bits(self.dim) as usize).div_ceil(8)
        } else {
            vals
        }
    }

    fn check_batch(&self, batch: &SparseBatch) -> Result<()> {
        if batch.k != self.k || batch.dim != self.dim {
            bail!(
                "sparse codec (d={}, k={}) fed batch (d={}, k={})",
                self.dim, self.k, batch.dim, batch.k
            );
        }
        // report each slice against rows*k on its own — with rows == 0
        // a joint "X values / Y indices" message blamed both slices even
        // when only one was non-empty
        let n = batch.rows * self.k;
        if batch.values.len() != n {
            bail!(
                "sparse batch arity mismatch: {} values for rows*k={n} (rows={})",
                batch.values.len(),
                batch.rows
            );
        }
        if batch.indices.len() != n {
            bail!(
                "sparse batch arity mismatch: {} indices for rows*k={n} (rows={})",
                batch.indices.len(),
                batch.rows
            );
        }
        Ok(())
    }
}

impl Codec for SparseCodec {
    fn name(&self) -> &'static str {
        if self.send_indices {
            "topk"
        } else {
            "size_reduction"
        }
    }

    fn size_model(&self) -> SizeModel {
        if self.send_indices {
            SizeModel::topk(self.dim, self.k)
        } else {
            SizeModel::size_reduction(self.dim, self.k)
        }
    }

    fn meta(&self, rows: usize, pass: Pass) -> PayloadMeta {
        PayloadMeta::Sparse {
            rows,
            dim: self.dim,
            k: self.k,
            with_indices: self.with_indices(pass),
        }
    }

    fn expected_wire_bytes(&self, rows: usize, pass: Pass) -> Option<usize> {
        Some(self.content_bytes(rows, pass))
    }

    fn encode_into(&self, batch: &Batch, pass: Pass, out: &mut Vec<u8>) -> Result<()> {
        let Batch::Sparse(batch) = batch else {
            bail!("sparse codec fed a non-sparse batch");
        };
        self.check_batch(batch)?;
        out.reserve(self.content_bytes(batch.rows, pass));
        extend_f32s_le(out, &batch.values);
        if self.with_indices(pass) {
            // validate before packing so an error never leaves partial
            // index words appended to the frame buffer
            if let Some(&i) = batch.indices.iter().find(|&&i| i < 0 || i as usize >= self.dim) {
                bail!("index {i} out of range for d={}", self.dim);
            }
            let nbits = index_bits(self.dim);
            let mut w = BitPacker::new(out);
            for &i in &batch.indices {
                w.write(i as u64, nbits);
            }
            w.finish();
        }
        Ok(())
    }

    fn decode_into(&self, payload: &Payload, pass: Pass, out: &mut Option<Batch>) -> Result<()> {
        let (mut values, mut indices) = scratch_sparse(out);
        let PayloadMeta::Sparse { rows, dim, k, with_indices } = payload.meta else {
            bail!("payload is not sparse");
        };
        if dim != self.dim || k != self.k {
            bail!("sparse payload geometry mismatch");
        }
        if with_indices != self.with_indices(pass) {
            bail!("sparse payload index presence mismatch for {pass:?}");
        }
        let expect = self.content_bytes(rows, pass);
        if payload.bytes.len() != expect {
            bail!("sparse payload wrong length: {} != {expect}", payload.bytes.len());
        }
        let n = rows * k;
        let val_bytes = n * 4;
        let bytes = &payload.bytes;
        read_f32s_le_into(&bytes[..val_bytes], &mut values);
        indices.reserve(n);
        if with_indices {
            let nbits = index_bits(self.dim);
            let mut r = BitReader::new(&bytes[val_bytes..]);
            for _ in 0..n {
                let Some(v) = r.read(nbits) else {
                    bail!("sparse payload index section truncated");
                };
                if v as usize >= self.dim {
                    bail!("decoded index {v} out of range");
                }
                indices.push(v as i32);
            }
        } else {
            // size reduction (or backward pass): indices are implicit 0..k
            for _ in 0..rows {
                indices.extend(0..self.k as i32);
            }
        }
        *out = Some(Batch::Sparse(SparseBatch {
            rows,
            dim: self.dim,
            k: self.k,
            values,
            indices,
        }));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::size_model::SizeModel;
    use crate::util::Rng;

    fn random_sparse(rng: &mut Rng, rows: usize, dim: usize, k: usize) -> SparseBatch {
        let mut values = Vec::new();
        let mut indices = Vec::new();
        for _ in 0..rows {
            let mut all: Vec<i32> = (0..dim as i32).collect();
            rng.shuffle(&mut all);
            let mut sel = all[..k].to_vec();
            sel.sort_unstable();
            for &i in &sel {
                indices.push(i);
                values.push(rng.normal());
            }
        }
        SparseBatch { rows, dim, k, values, indices }
    }

    #[test]
    fn roundtrip_forward_with_indices() {
        let mut rng = Rng::new(1);
        for (dim, k) in [(128, 3), (128, 13), (300, 2), (600, 14), (1280, 9), (16, 16)] {
            let codec = SparseCodec::topk(dim, k);
            let batch = random_sparse(&mut rng, 32, dim, k);
            let p = codec.encode(&Batch::Sparse(batch.clone()), Pass::Forward).unwrap();
            let back = codec.decode(&p, Pass::Forward).unwrap();
            assert_eq!(Batch::Sparse(batch), back, "d={dim} k={k}");
        }
    }

    #[test]
    fn roundtrip_backward_values_only() {
        let mut rng = Rng::new(2);
        let codec = SparseCodec::topk(128, 6);
        let mut batch = random_sparse(&mut rng, 8, 128, 6);
        let p = codec.encode(&Batch::Sparse(batch.clone()), Pass::Backward).unwrap();
        // backward payload must be exactly rows*k*4 bytes — no indices
        assert_eq!(p.wire_bytes(), 8 * 6 * 4);
        assert_eq!(codec.expected_wire_bytes(8, Pass::Backward), Some(8 * 6 * 4));
        let Batch::Sparse(back) = codec.decode(&p, Pass::Backward).unwrap() else {
            panic!("expected sparse batch");
        };
        assert_eq!(back.values, batch.values);
        // decoded indices are the implicit 0..k (receiver rewires by its own
        // cached indices, see coordinator::feature_owner)
        batch.indices = (0..8).flat_map(|_| 0..6).collect();
        assert_eq!(back.indices, batch.indices);
    }

    #[test]
    fn forward_size_matches_table2() {
        // k/d * (1 + ceil(log2 d)/32) within bit-padding slack
        for (dim, k) in [(128usize, 3usize), (300, 4), (600, 9), (1280, 2)] {
            let codec = SparseCodec::topk(dim, k);
            let mut rng = Rng::new(3);
            let rows = 32;
            let batch = random_sparse(&mut rng, rows, dim, k);
            let p = codec.encode(&Batch::Sparse(batch), Pass::Forward).unwrap();
            let analytic = SizeModel::topk(dim, k).forward_fraction() * (rows * dim * 4) as f64;
            let measured = p.wire_bytes() as f64;
            assert!(
                (measured - analytic).abs() <= 8.0,
                "d={dim} k={k}: measured {measured} analytic {analytic}"
            );
            // expected_wire_bytes is the exact version of the same number
            assert_eq!(p.wire_bytes(), codec.expected_wire_bytes(rows, Pass::Forward).unwrap());
        }
    }

    #[test]
    fn size_reduction_sends_no_indices() {
        let codec = SparseCodec::size_reduction(128, 6);
        let batch = SparseBatch {
            rows: 4,
            dim: 128,
            k: 6,
            values: vec![1.0; 24],
            indices: (0..4).flat_map(|_| 0..6).collect(),
        };
        let p = codec.encode(&Batch::Sparse(batch.clone()), Pass::Forward).unwrap();
        assert_eq!(p.wire_bytes(), 4 * 6 * 4);
        let back = codec.decode(&p, Pass::Forward).unwrap();
        assert_eq!(back, Batch::Sparse(batch));
    }

    /// dim == 1 edge: `index_bits(1) == 0`, so the packed index section
    /// is empty and the forward wire is exactly the f32 values.
    #[test]
    fn dim_one_packs_zero_bit_indices() {
        let codec = SparseCodec::topk(1, 1);
        let batch = SparseBatch {
            rows: 4,
            dim: 1,
            k: 1,
            values: vec![1.0, 2.0, 3.0, 4.0],
            indices: vec![0; 4],
        };
        let p = codec.encode(&Batch::Sparse(batch.clone()), Pass::Forward).unwrap();
        assert_eq!(p.wire_bytes(), 4 * 4);
        assert_eq!(codec.expected_wire_bytes(4, Pass::Forward), Some(16));
        let back = codec.decode(&p, Pass::Forward).unwrap();
        assert_eq!(back, Batch::Sparse(batch));
    }

    /// rows == 0 with a non-empty slice must blame exactly the slice
    /// that is wrong, not a joint values/indices message.
    #[test]
    fn rows_zero_arity_errors_name_the_offending_slice() {
        let codec = SparseCodec::topk(128, 6);
        let bad_vals =
            SparseBatch { rows: 0, dim: 128, k: 6, values: vec![1.0], indices: vec![] };
        let err =
            codec.encode(&Batch::Sparse(bad_vals), Pass::Forward).unwrap_err().to_string();
        assert!(err.contains("1 values"), "{err}");
        assert!(!err.contains("indices"), "{err}");
        let bad_idx = SparseBatch { rows: 0, dim: 128, k: 6, values: vec![], indices: vec![3] };
        let err =
            codec.encode(&Batch::Sparse(bad_idx), Pass::Forward).unwrap_err().to_string();
        assert!(err.contains("1 indices"), "{err}");
    }

    #[test]
    fn rejects_geometry_mismatch() {
        let codec = SparseCodec::topk(128, 6);
        let batch = SparseBatch {
            rows: 1,
            dim: 64,
            k: 6,
            values: vec![0.0; 6],
            indices: vec![0, 1, 2, 3, 4, 5],
        };
        assert!(codec.encode(&Batch::Sparse(batch), Pass::Forward).is_err());
    }

    #[test]
    fn rejects_out_of_range_index() {
        let codec = SparseCodec::topk(16, 2);
        let batch = SparseBatch {
            rows: 1,
            dim: 16,
            k: 2,
            values: vec![1.0, 2.0],
            indices: vec![3, 16],
        };
        assert!(codec.encode(&Batch::Sparse(batch), Pass::Forward).is_err());
    }

    #[test]
    fn rejects_wrong_length_payload() {
        let codec = SparseCodec::topk(128, 6);
        let mut rng = Rng::new(4);
        let batch = random_sparse(&mut rng, 4, 128, 6);
        let p = codec.encode(&Batch::Sparse(batch), Pass::Forward).unwrap();
        let cut = Payload::new(p.meta, p.bytes[..p.bytes.len() - 4].to_vec());
        assert!(codec.decode(&cut, Pass::Forward).is_err());
        // trailing garbage is equally rejected (exact-length contract)
        let mut longer = p.bytes.to_vec();
        longer.push(0xFF);
        let extended = Payload::new(p.meta, longer);
        assert!(codec.decode(&extended, Pass::Forward).is_err());
    }

    #[test]
    fn decode_into_reuses_scratch() {
        let codec = SparseCodec::topk(128, 6);
        let mut rng = Rng::new(9);
        let batch = random_sparse(&mut rng, 4, 128, 6);
        let p = codec.encode(&Batch::Sparse(batch.clone()), Pass::Forward).unwrap();
        let mut slot = None;
        codec.decode_into(&p, Pass::Forward, &mut slot).unwrap();
        let Some(Batch::Sparse(s)) = slot.as_ref() else { panic!("expected sparse") };
        assert_eq!(s.values, batch.values);
        assert_eq!(s.indices, batch.indices);
        let (vp, ip) = (s.values.as_ptr(), s.indices.as_ptr());
        // second decode into the same slot: same buffers, no realloc
        codec.decode_into(&p, Pass::Forward, &mut slot).unwrap();
        let Some(Batch::Sparse(s)) = slot.as_ref() else { panic!("expected sparse") };
        assert_eq!((s.values.as_ptr(), s.indices.as_ptr()), (vp, ip));
        assert_eq!(s.values, batch.values);
    }

    #[test]
    fn to_dense_scatter() {
        let batch = SparseBatch {
            rows: 2,
            dim: 5,
            k: 2,
            values: vec![1.0, 2.0, 3.0, 4.0],
            indices: vec![0, 3, 1, 4],
        };
        let dense = batch.to_dense();
        assert_eq!(dense.row(0), &[1.0, 0.0, 0.0, 2.0, 0.0]);
        assert_eq!(dense.row(1), &[0.0, 3.0, 0.0, 0.0, 4.0]);
    }
}
