//! Dense codec: raw f32 rows — the no-compression baseline (vanilla SL)
//! and the backward path of quantization / L1 (paper Table 2: size 1).

use anyhow::{bail, Result};

use super::{DenseBatch, Payload};

#[derive(Clone, Copy, Debug)]
pub struct DenseCodec {
    pub dim: usize,
}

impl DenseCodec {
    pub fn new(dim: usize) -> Self {
        DenseCodec { dim }
    }

    pub fn encode(&self, batch: &DenseBatch) -> Result<Payload> {
        if batch.dim != self.dim {
            bail!("dense codec d={} fed batch d={}", self.dim, batch.dim);
        }
        let mut bytes = Vec::with_capacity(batch.data.len() * 4);
        for v in &batch.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        Ok(Payload::Dense { rows: batch.rows, dim: self.dim, bytes })
    }

    pub fn decode(&self, payload: &Payload) -> Result<DenseBatch> {
        let Payload::Dense { rows, dim, bytes } = payload else {
            bail!("payload is not dense");
        };
        if *dim != self.dim {
            bail!("dense payload geometry mismatch");
        }
        if bytes.len() != rows * dim * 4 {
            bail!("dense payload wrong length: {} != {}", bytes.len(), rows * dim * 4);
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(DenseBatch::new(*rows, *dim, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let codec = DenseCodec::new(300);
        let batch = DenseBatch::new(8, 300, (0..2400).map(|_| rng.normal()).collect());
        let p = codec.encode(&batch).unwrap();
        assert_eq!(p.wire_bytes(), 8 * 300 * 4);
        assert!((p.compressed_size_pct() - 100.0).abs() < 1e-9);
        assert_eq!(codec.decode(&p).unwrap(), batch);
    }

    #[test]
    fn rejects_wrong_length() {
        let codec = DenseCodec::new(4);
        let p = Payload::Dense { rows: 2, dim: 4, bytes: vec![0; 31] };
        assert!(codec.decode(&p).is_err());
    }
}
