//! Dense codec: raw f32 rows — the no-compression baseline (vanilla SL)
//! and the backward path of quantization / L1 (paper Table 2: size 1).

use anyhow::{bail, Result};

use crate::util::{extend_f32s_le, read_f32s_le_into};

use super::codec::scratch_f32;
use super::{Batch, Codec, DenseBatch, Pass, Payload, PayloadMeta, SizeModel};

#[derive(Clone, Copy, Debug)]
pub struct DenseCodec {
    pub dim: usize,
}

impl DenseCodec {
    pub fn new(dim: usize) -> Self {
        DenseCodec { dim }
    }
}

impl Codec for DenseCodec {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn size_model(&self) -> SizeModel {
        SizeModel::Dense
    }

    fn meta(&self, rows: usize, _pass: Pass) -> PayloadMeta {
        PayloadMeta::Dense { rows, dim: self.dim }
    }

    fn expected_wire_bytes(&self, rows: usize, _pass: Pass) -> Option<usize> {
        Some(rows * self.dim * 4)
    }

    fn encode_into(&self, batch: &Batch, _pass: Pass, out: &mut Vec<u8>) -> Result<()> {
        let Batch::Dense(batch) = batch else {
            bail!("dense codec fed a non-dense batch");
        };
        if batch.dim != self.dim {
            bail!("dense codec d={} fed batch d={}", self.dim, batch.dim);
        }
        extend_f32s_le(out, &batch.data);
        Ok(())
    }

    fn decode_into(&self, payload: &Payload, _pass: Pass, out: &mut Option<Batch>) -> Result<()> {
        let mut data = scratch_f32(out);
        let PayloadMeta::Dense { rows, dim } = payload.meta else {
            bail!("payload is not dense");
        };
        if dim != self.dim {
            bail!("dense payload geometry mismatch");
        }
        if payload.bytes.len() != rows * dim * 4 {
            bail!(
                "dense payload wrong length: {} != {}",
                payload.bytes.len(),
                rows * dim * 4
            );
        }
        read_f32s_le_into(&payload.bytes, &mut data);
        *out = Some(Batch::Dense(DenseBatch::new(rows, dim, data)));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let codec = DenseCodec::new(300);
        let batch = Batch::Dense(DenseBatch::new(
            8,
            300,
            (0..2400).map(|_| rng.normal()).collect(),
        ));
        let p = codec.encode(&batch, Pass::Forward).unwrap();
        assert_eq!(p.wire_bytes(), 8 * 300 * 4);
        assert!((p.compressed_size_pct() - 100.0).abs() < 1e-9);
        assert_eq!(codec.decode(&p, Pass::Forward).unwrap(), batch);
        // both passes are identical for the dense baseline
        assert_eq!(codec.meta(8, Pass::Forward), codec.meta(8, Pass::Backward));
    }

    #[test]
    fn rejects_wrong_length() {
        let codec = DenseCodec::new(4);
        let p = Payload::dense(2, 4, vec![0; 31]);
        assert!(codec.decode(&p, Pass::Forward).is_err());
    }
}
