//! Instance-level compression codecs for the cut-layer traffic (paper §3).
//!
//! Every method compresses a batch of per-instance vectors independently
//! ("instance level", §3): the wire payload concatenates the rows. The
//! measured payload sizes must match the paper's Table 2 analytic model —
//! `size_model` carries those formulas and `codec::Codec::expected_wire_bytes`
//! plus the roundtrip fuzz tests cross-check them against real wire bytes.
//!
//! All codecs are reached through the object-safe [`Codec`] trait and the
//! [`codec_for`] registry — the coordinator parties never name a concrete
//! codec type, so a new wire layout is one new `impl Codec` plus a registry
//! arm, touching neither party.

pub mod adapt;
pub mod codec;
pub mod dense;
pub mod l1;
pub mod quant;
pub mod size_model;
pub mod sparse;

pub use adapt::{AdaptPolicy, AdaptSignals};
pub use codec::{
    codec_for, codec_for_layout, scratch_f32, scratch_quant, scratch_sparse, Batch, Codec,
    CodecSpec, IndexLayout,
};
pub use dense::DenseCodec;
pub use l1::L1Codec;
pub use quant::{QuantBatch, QuantCodec};
pub use size_model::SizeModel;
pub use sparse::SparseCodec;

use crate::util::Bytes;


/// A batch of dense per-instance vectors: `rows` x `dim`, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseBatch {
    pub rows: usize,
    pub dim: usize,
    pub data: Vec<f32>,
}

impl DenseBatch {
    pub fn new(rows: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * dim);
        DenseBatch { rows, dim, data }
    }

    pub fn zeros(rows: usize, dim: usize) -> Self {
        DenseBatch { rows, dim, data: vec![0.0; rows * dim] }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }
}

/// A batch in sparse (values + indices) form, k entries per row.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseBatch {
    pub rows: usize,
    pub dim: usize,
    pub k: usize,
    /// rows * k selected values, row-major.
    pub values: Vec<f32>,
    /// rows * k indices in [0, dim), row-major, ascending within a row.
    pub indices: Vec<i32>,
}

impl SparseBatch {
    pub fn to_dense(&self) -> DenseBatch {
        let mut out = DenseBatch::zeros(self.rows, self.dim);
        for r in 0..self.rows {
            for j in 0..self.k {
                let idx = self.indices[r * self.k + j] as usize;
                out.data[r * self.dim + idx] = self.values[r * self.k + j];
            }
        }
        out
    }
}

/// Direction of a message (Table 2 distinguishes forward/backward sizes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    Forward,
    Backward,
}

/// Payload descriptor: which wire layout the content bytes use, plus its
/// geometry. Kept separate from the content so the framing layer can write
/// it ahead of codec output that streams straight into the frame buffer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PayloadMeta {
    /// values (+ bit-packed indices on the forward pass).
    Sparse { rows: usize, dim: usize, k: usize, with_indices: bool },
    /// b-bit packed codes + per-row (min, max) header.
    Quantized { rows: usize, dim: usize, bits: u8 },
    /// raw f32 rows.
    Dense { rows: usize, dim: usize },
    /// variable-k sparse (L1): per-row counts + values + packed indices.
    VarSparse { rows: usize, dim: usize },
}

impl PayloadMeta {
    /// (rows, dim) of the batch this payload carries.
    pub fn geometry(&self) -> (usize, usize) {
        match *self {
            PayloadMeta::Sparse { rows, dim, .. }
            | PayloadMeta::Quantized { rows, dim, .. }
            | PayloadMeta::Dense { rows, dim }
            | PayloadMeta::VarSparse { rows, dim } => (rows, dim),
        }
    }
}

/// What travels on the wire after compression: a descriptor plus the
/// codec's content bytes.
///
/// The content is a refcounted [`Bytes`] view: on the receive path it
/// borrows straight from the pooled frame buffer (zero-copy decode),
/// while senders build it from an owned `Vec<u8>` via `Into`.
#[derive(Clone, Debug, PartialEq)]
pub struct Payload {
    pub meta: PayloadMeta,
    pub bytes: Bytes,
}

impl Payload {
    pub fn new(meta: PayloadMeta, bytes: impl Into<Bytes>) -> Self {
        Payload { meta, bytes: bytes.into() }
    }

    pub fn sparse(
        rows: usize,
        dim: usize,
        k: usize,
        with_indices: bool,
        bytes: impl Into<Bytes>,
    ) -> Self {
        Payload::new(PayloadMeta::Sparse { rows, dim, k, with_indices }, bytes)
    }

    pub fn quantized(rows: usize, dim: usize, bits: u8, bytes: impl Into<Bytes>) -> Self {
        Payload::new(PayloadMeta::Quantized { rows, dim, bits }, bytes)
    }

    pub fn dense(rows: usize, dim: usize, bytes: impl Into<Bytes>) -> Self {
        Payload::new(PayloadMeta::Dense { rows, dim }, bytes)
    }

    pub fn var_sparse(rows: usize, dim: usize, bytes: impl Into<Bytes>) -> Self {
        Payload::new(PayloadMeta::VarSparse { rows, dim }, bytes)
    }

    /// Bytes actually sent for the tensor content (excluding framing).
    pub fn wire_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Uncompressed reference size (rows * dim * 4), the paper's "100".
    pub fn dense_reference_bytes(&self) -> usize {
        let (rows, dim) = self.meta.geometry();
        rows * dim * 4
    }

    /// Paper's "compressed size" in percent of the dense reference.
    pub fn compressed_size_pct(&self) -> f64 {
        100.0 * self.wire_bytes() as f64 / self.dense_reference_bytes() as f64
    }
}
