//! Quantization codec: b-bit packed codes + per-row (min, max) f32 header.
//!
//! The quantize/dequantize math itself runs in-graph (L1 kernel, paper
//! Eq. 2); this codec only packs the integer codes for the wire. The
//! backward pass is dense (paper Table 2: gradient quantization hurts too
//! much, §3.1) — the codec owns both directions, so `Pass::Backward`
//! expects/produces a dense batch.

use anyhow::{bail, Result};

use crate::util::{BitPacker, BitReader};

use super::codec::scratch_quant;
use super::{Batch, Codec, DenseBatch, DenseCodec, Pass, Payload, PayloadMeta, SizeModel};

/// Codes batch as produced by the `quant_b*` bottom_fwd artifact: f32
/// tensors holding integers in [0, 2^bits) plus per-row min/max.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantBatch {
    pub rows: usize,
    pub dim: usize,
    /// integer codes, stored as f32 by the artifact.
    pub codes: Vec<f32>,
    pub o_min: Vec<f32>,
    pub o_max: Vec<f32>,
}

#[derive(Clone, Copy, Debug)]
pub struct QuantCodec {
    pub dim: usize,
    pub bits: u8,
}

impl QuantCodec {
    pub fn new(dim: usize, bits: u8) -> Self {
        assert!((1..=16).contains(&bits));
        QuantCodec { dim, bits }
    }

    /// Forward content: per row [min f32, max f32]; then all codes packed.
    fn forward_bytes(&self, rows: usize) -> usize {
        rows * 8 + (rows * self.dim * self.bits as usize).div_ceil(8)
    }

    /// Dequantize to a dense batch (bin midpoints, Eq. 2) — used by
    /// analysis tooling; the label owner's artifact does this in-graph.
    pub fn dequantize(&self, batch: &QuantBatch) -> DenseBatch {
        let levels = (1u64 << self.bits) as f32;
        let mut data = Vec::with_capacity(batch.codes.len());
        for r in 0..batch.rows {
            let span = (batch.o_max[r] - batch.o_min[r]).max(1e-12);
            for j in 0..batch.dim {
                let c = batch.codes[r * batch.dim + j];
                data.push(batch.o_min[r] + (c + 0.5) * span / levels);
            }
        }
        DenseBatch::new(batch.rows, batch.dim, data)
    }
}

impl Codec for QuantCodec {
    fn name(&self) -> &'static str {
        "quant"
    }

    fn size_model(&self) -> SizeModel {
        SizeModel::quant(self.dim, self.bits as usize)
    }

    fn meta(&self, rows: usize, pass: Pass) -> PayloadMeta {
        match pass {
            Pass::Forward => PayloadMeta::Quantized { rows, dim: self.dim, bits: self.bits },
            Pass::Backward => PayloadMeta::Dense { rows, dim: self.dim },
        }
    }

    fn expected_wire_bytes(&self, rows: usize, pass: Pass) -> Option<usize> {
        Some(match pass {
            Pass::Forward => self.forward_bytes(rows),
            Pass::Backward => rows * self.dim * 4,
        })
    }

    fn encode_into(&self, batch: &Batch, pass: Pass, out: &mut Vec<u8>) -> Result<()> {
        match pass {
            Pass::Forward => {
                let Batch::Quant(batch) = batch else {
                    bail!("quant codec fed a non-quant batch on the forward pass");
                };
                if batch.dim != self.dim {
                    bail!("quant codec d={} fed batch d={}", self.dim, batch.dim);
                }
                if batch.codes.len() != batch.rows * batch.dim
                    || batch.o_min.len() != batch.rows
                    || batch.o_max.len() != batch.rows
                {
                    bail!("quant batch geometry inconsistent");
                }
                out.reserve(self.forward_bytes(batch.rows));
                for r in 0..batch.rows {
                    out.extend_from_slice(&batch.o_min[r].to_le_bytes());
                    out.extend_from_slice(&batch.o_max[r].to_le_bytes());
                }
                let max_code = (1u64 << self.bits) - 1;
                // validate before packing so an error never leaves
                // partial code words appended to the frame buffer
                if let Some(&c) = batch.codes.iter().find(|&&c| {
                    let ci = c as i64;
                    ci < 0 || ci as u64 > max_code
                }) {
                    bail!("code {c} out of range for {} bits", self.bits);
                }
                let mut w = BitPacker::new(out);
                for &c in &batch.codes {
                    w.write(c as i64 as u64, self.bits as u32);
                }
                w.finish();
                Ok(())
            }
            // Table 2: the gradient travels dense — delegate to the one
            // implementation of the dense wire layout
            Pass::Backward => DenseCodec::new(self.dim).encode_into(batch, pass, out),
        }
    }

    fn decode_into(&self, payload: &Payload, pass: Pass, out: &mut Option<Batch>) -> Result<()> {
        match pass {
            Pass::Forward => {
                let (mut codes, mut o_min, mut o_max) = scratch_quant(out);
                let PayloadMeta::Quantized { rows, dim, bits } = payload.meta else {
                    bail!("payload is not quantized");
                };
                if dim != self.dim || bits != self.bits {
                    bail!("quant payload geometry mismatch");
                }
                if payload.bytes.len() != self.forward_bytes(rows) {
                    bail!(
                        "quant payload wrong length: {} != {}",
                        payload.bytes.len(),
                        self.forward_bytes(rows)
                    );
                }
                let bytes = &payload.bytes;
                let header = rows * 8;
                o_min.reserve(rows);
                o_max.reserve(rows);
                for r in 0..rows {
                    let b = &bytes[r * 8..r * 8 + 8];
                    o_min.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                    o_max.push(f32::from_le_bytes([b[4], b[5], b[6], b[7]]));
                }
                let mut reader = BitReader::new(&bytes[header..]);
                codes.reserve(rows * dim);
                for _ in 0..rows * dim {
                    let Some(v) = reader.read(self.bits as u32) else {
                        bail!("quant payload truncated codes");
                    };
                    codes.push(v as f32);
                }
                *out = Some(Batch::Quant(QuantBatch { rows, dim, codes, o_min, o_max }));
                Ok(())
            }
            Pass::Backward => DenseCodec::new(self.dim).decode_into(payload, pass, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::size_model::SizeModel;
    use crate::util::Rng;

    fn random_quant(rng: &mut Rng, rows: usize, dim: usize, bits: u8) -> QuantBatch {
        let levels = (1u64 << bits) as f32;
        QuantBatch {
            rows,
            dim,
            codes: (0..rows * dim)
                .map(|_| (rng.next_f32() * levels).floor().min(levels - 1.0))
                .collect(),
            o_min: (0..rows).map(|_| -rng.next_f32()).collect(),
            o_max: (0..rows).map(|_| 1.0 + rng.next_f32()).collect(),
        }
    }

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(1);
        for bits in [1u8, 2, 4, 8] {
            let codec = QuantCodec::new(128, bits);
            let batch = Batch::Quant(random_quant(&mut rng, 16, 128, bits));
            let p = codec.encode(&batch, Pass::Forward).unwrap();
            assert_eq!(p.wire_bytes(), codec.expected_wire_bytes(16, Pass::Forward).unwrap());
            let back = codec.decode(&p, Pass::Forward).unwrap();
            assert_eq!(batch, back, "bits={bits}");
        }
    }

    #[test]
    fn backward_pass_is_dense() {
        let mut rng = Rng::new(9);
        let codec = QuantCodec::new(32, 2);
        let dense = DenseBatch::new(4, 32, (0..128).map(|_| rng.normal()).collect());
        let p = codec.encode(&Batch::Dense(dense.clone()), Pass::Backward).unwrap();
        assert_eq!(p.wire_bytes(), 4 * 32 * 4);
        assert_eq!(p.meta, PayloadMeta::Dense { rows: 4, dim: 32 });
        let back = codec.decode(&p, Pass::Backward).unwrap();
        assert_eq!(back, Batch::Dense(dense));
        // a quant batch on the backward pass is a caller bug
        let q = random_quant(&mut rng, 4, 32, 2);
        assert!(codec.encode(&Batch::Quant(q), Pass::Backward).is_err());
    }

    #[test]
    fn wire_size_matches_table2_asymptotically() {
        // 2^b / N with N = 32, i.e. codes dominate for large d; the
        // per-row min/max header is 8 bytes.
        let mut rng = Rng::new(2);
        for bits in [2u8, 4] {
            let (rows, dim) = (32, 1024);
            let codec = QuantCodec::new(dim, bits);
            let batch = Batch::Quant(random_quant(&mut rng, rows, dim, bits));
            let p = codec.encode(&batch, Pass::Forward).unwrap();
            let analytic =
                SizeModel::quant(dim, bits as usize).forward_fraction() * (rows * dim * 4) as f64;
            let measured = (p.wire_bytes() - rows * 8) as f64; // codes only
            assert!(
                (measured - analytic).abs() / analytic < 0.01,
                "bits={bits}: {measured} vs {analytic}"
            );
        }
    }

    #[test]
    fn rejects_out_of_range_code() {
        let codec = QuantCodec::new(8, 2);
        let batch = QuantBatch {
            rows: 1,
            dim: 8,
            codes: vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0], // 4 > 3
            o_min: vec![0.0],
            o_max: vec![1.0],
        };
        assert!(codec.encode(&Batch::Quant(batch), Pass::Forward).is_err());
    }

    #[test]
    fn dequantize_midpoints() {
        let codec = QuantCodec::new(4, 2);
        let batch = QuantBatch {
            rows: 1,
            dim: 4,
            codes: vec![0.0, 1.0, 2.0, 3.0],
            o_min: vec![0.0],
            o_max: vec![4.0],
        };
        let dense = codec.dequantize(&batch);
        assert_eq!(dense.row(0), &[0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn truncated_payload_rejected() {
        let mut rng = Rng::new(3);
        let codec = QuantCodec::new(64, 4);
        let batch = Batch::Quant(random_quant(&mut rng, 4, 64, 4));
        let p = codec.encode(&batch, Pass::Forward).unwrap();
        let cut = Payload::new(p.meta, p.bytes[..10].to_vec());
        assert!(codec.decode(&cut, Pass::Forward).is_err());
    }
}
