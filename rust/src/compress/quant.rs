//! Quantization codec: b-bit packed codes + per-row (min, max) f32 header.
//!
//! The quantize/dequantize math itself runs in-graph (L1 kernel, paper
//! Eq. 2); this codec only packs the integer codes for the wire. Backward
//! is dense (paper Table 2: gradient quantization hurts too much, §3.1).

use anyhow::{bail, Result};

use crate::util::{BitReader, BitWriter};

use super::{DenseBatch, Payload};

/// Codes batch as produced by the `quant_b*` bottom_fwd artifact: f32
/// tensors holding integers in [0, 2^bits) plus per-row min/max.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantBatch {
    pub rows: usize,
    pub dim: usize,
    /// integer codes, stored as f32 by the artifact.
    pub codes: Vec<f32>,
    pub o_min: Vec<f32>,
    pub o_max: Vec<f32>,
}

#[derive(Clone, Copy, Debug)]
pub struct QuantCodec {
    pub dim: usize,
    pub bits: u8,
}

impl QuantCodec {
    pub fn new(dim: usize, bits: u8) -> Self {
        assert!(bits >= 1 && bits <= 16);
        QuantCodec { dim, bits }
    }

    /// Wire layout: per row [min f32, max f32]; then all codes bit-packed.
    pub fn encode(&self, batch: &QuantBatch) -> Result<Payload> {
        if batch.dim != self.dim {
            bail!("quant codec d={} fed batch d={}", self.dim, batch.dim);
        }
        if batch.codes.len() != batch.rows * batch.dim
            || batch.o_min.len() != batch.rows
            || batch.o_max.len() != batch.rows
        {
            bail!("quant batch geometry inconsistent");
        }
        let mut bytes = Vec::with_capacity(batch.rows * 8 + batch.codes.len() * self.bits as usize / 8 + 8);
        for r in 0..batch.rows {
            bytes.extend_from_slice(&batch.o_min[r].to_le_bytes());
            bytes.extend_from_slice(&batch.o_max[r].to_le_bytes());
        }
        let max_code = (1u64 << self.bits) - 1;
        let mut w = BitWriter::with_capacity_bits(batch.codes.len() * self.bits as usize);
        for &c in &batch.codes {
            let ci = c as i64;
            if ci < 0 || ci as u64 > max_code {
                bail!("code {c} out of range for {} bits", self.bits);
            }
            w.write(ci as u64, self.bits as u32);
        }
        bytes.extend_from_slice(&w.into_bytes());
        Ok(Payload::Quantized {
            rows: batch.rows,
            dim: self.dim,
            bits: self.bits,
            bytes,
        })
    }

    pub fn decode(&self, payload: &Payload) -> Result<QuantBatch> {
        let Payload::Quantized { rows, dim, bits, bytes } = payload else {
            bail!("payload is not quantized");
        };
        if *dim != self.dim || *bits != self.bits {
            bail!("quant payload geometry mismatch");
        }
        let header = rows * 8;
        if bytes.len() < header {
            bail!("quant payload truncated header");
        }
        let mut o_min = Vec::with_capacity(*rows);
        let mut o_max = Vec::with_capacity(*rows);
        for r in 0..*rows {
            let b = &bytes[r * 8..r * 8 + 8];
            o_min.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            o_max.push(f32::from_le_bytes([b[4], b[5], b[6], b[7]]));
        }
        let mut reader = BitReader::new(&bytes[header..]);
        let mut codes = Vec::with_capacity(rows * dim);
        for _ in 0..rows * dim {
            let Some(v) = reader.read(self.bits as u32) else {
                bail!("quant payload truncated codes");
            };
            codes.push(v as f32);
        }
        Ok(QuantBatch {
            rows: *rows,
            dim: *dim,
            codes,
            o_min,
            o_max,
        })
    }

    /// Dequantize to a dense batch (bin midpoints, Eq. 2) — used by
    /// analysis tooling; the label owner's artifact does this in-graph.
    pub fn dequantize(&self, batch: &QuantBatch) -> DenseBatch {
        let levels = (1u64 << self.bits) as f32;
        let mut data = Vec::with_capacity(batch.codes.len());
        for r in 0..batch.rows {
            let span = (batch.o_max[r] - batch.o_min[r]).max(1e-12);
            for j in 0..batch.dim {
                let c = batch.codes[r * batch.dim + j];
                data.push(batch.o_min[r] + (c + 0.5) * span / levels);
            }
        }
        DenseBatch::new(batch.rows, batch.dim, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::size_model::SizeModel;
    use crate::util::Rng;

    fn random_quant(rng: &mut Rng, rows: usize, dim: usize, bits: u8) -> QuantBatch {
        let levels = (1u64 << bits) as f32;
        QuantBatch {
            rows,
            dim,
            codes: (0..rows * dim)
                .map(|_| (rng.next_f32() * levels).floor().min(levels - 1.0))
                .collect(),
            o_min: (0..rows).map(|_| -rng.next_f32()).collect(),
            o_max: (0..rows).map(|_| 1.0 + rng.next_f32()).collect(),
        }
    }

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(1);
        for bits in [1u8, 2, 4, 8] {
            let codec = QuantCodec::new(128, bits);
            let batch = random_quant(&mut rng, 16, 128, bits);
            let p = codec.encode(&batch).unwrap();
            let back = codec.decode(&p).unwrap();
            assert_eq!(batch, back, "bits={bits}");
        }
    }

    #[test]
    fn wire_size_matches_table2_asymptotically() {
        // 2^b / N with N = 32, i.e. codes dominate for large d; the
        // per-row min/max header is 8 bytes.
        let mut rng = Rng::new(2);
        for bits in [2u8, 4] {
            let (rows, dim) = (32, 1024);
            let codec = QuantCodec::new(dim, bits);
            let batch = random_quant(&mut rng, rows, dim, bits);
            let p = codec.encode(&batch).unwrap();
            let analytic =
                SizeModel::quant(dim, bits as usize).forward_fraction() * (rows * dim * 4) as f64;
            let measured = (p.wire_bytes() - rows * 8) as f64; // codes only
            assert!(
                (measured - analytic).abs() / analytic < 0.01,
                "bits={bits}: {measured} vs {analytic}"
            );
        }
    }

    #[test]
    fn rejects_out_of_range_code() {
        let codec = QuantCodec::new(8, 2);
        let batch = QuantBatch {
            rows: 1,
            dim: 8,
            codes: vec![0.0, 1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0], // 4 > 3
            o_min: vec![0.0],
            o_max: vec![1.0],
        };
        assert!(codec.encode(&batch).is_err());
    }

    #[test]
    fn dequantize_midpoints() {
        let codec = QuantCodec::new(4, 2);
        let batch = QuantBatch {
            rows: 1,
            dim: 4,
            codes: vec![0.0, 1.0, 2.0, 3.0],
            o_min: vec![0.0],
            o_max: vec![4.0],
        };
        let dense = codec.dequantize(&batch);
        assert_eq!(dense.row(0), &[0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn truncated_payload_rejected() {
        let mut rng = Rng::new(3);
        let codec = QuantCodec::new(64, 4);
        let batch = random_quant(&mut rng, 4, 64, 4);
        let p = codec.encode(&batch).unwrap();
        if let Payload::Quantized { rows, dim, bits, bytes } = p {
            let cut = Payload::Quantized {
                rows,
                dim,
                bits,
                bytes: bytes[..10].to_vec(),
            };
            assert!(codec.decode(&cut).is_err());
        }
    }
}
