//! Analytic compressed-size model — paper Table 2.
//!
//! | method          | forward                          | backward |
//! |-----------------|----------------------------------|----------|
//! | size reduction  | k/d                              | k/d      |
//! | quantization b  | 2^b / N                          | 1        |
//! | top-k           | k/d * (1 + ceil(log2 d)/N)       | k/d      |
//! | top-k (leb128)  | k/d * (1 + 8*leb(d/k)/N) (est)   | k/d      |
//! | L1              | k/d * (1 + ceil(log2 d)/N) (var) | 1        |
//!
//! N = 32 (f32). The unit tests in each codec cross-check measured wire
//! bytes against these fractions; `examples/table2_sizes.rs` prints the
//! table with measured columns side by side.

pub const N_BITS: usize = 32;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SizeModel {
    SizeReduction { d: usize, k: usize },
    Quant { d: usize, bits: usize },
    Topk { d: usize, k: usize },
    /// Top-k with LEB128-delta indices: the index cost is the *expected*
    /// varint width for the mean ascending gap d/k, not ⌈log2 d⌉. An
    /// estimate — the true wire size is input-dependent.
    TopkLeb { d: usize, k: usize },
    /// L1: k is the *observed mean* nonzero count (varies per input).
    L1 { d: usize, k_mean: f64 },
    Dense,
}

impl SizeModel {
    pub fn size_reduction(d: usize, k: usize) -> Self {
        SizeModel::SizeReduction { d, k }
    }

    pub fn quant(d: usize, bits: usize) -> Self {
        SizeModel::Quant { d, bits }
    }

    pub fn topk(d: usize, k: usize) -> Self {
        SizeModel::Topk { d, k }
    }

    pub fn topk_leb(d: usize, k: usize) -> Self {
        SizeModel::TopkLeb { d, k }
    }

    pub fn index_overhead(d: usize) -> f64 {
        let r = crate::util::index_bits(d) as f64;
        1.0 + r / N_BITS as f64
    }

    /// Fraction of the dense size sent on the forward pass.
    pub fn forward_fraction(&self) -> f64 {
        match *self {
            SizeModel::SizeReduction { d, k } => k as f64 / d as f64,
            // Paper Table 2 prints "2^b/N", but its own Table 3 sizes
            // (2-bit -> 6.25%, 4-bit -> 12.5%, 1-bit -> 3.13%) are b/N —
            // the physically correct b bits per value. We use b/N.
            SizeModel::Quant { bits, .. } => bits as f64 / N_BITS as f64,
            SizeModel::Topk { d, k } => k as f64 / d as f64 * Self::index_overhead(d),
            SizeModel::TopkLeb { d, k } => {
                // expected LEB128 bytes for the mean gap d/k, as bits/N
                let gap = (d / k.max(1)).max(1) as u64;
                let leb_bits = 8.0 * crate::util::uleb128_len(gap) as f64;
                k as f64 / d as f64 * (1.0 + leb_bits / N_BITS as f64)
            }
            SizeModel::L1 { d, k_mean } => k_mean / d as f64 * Self::index_overhead(d),
            SizeModel::Dense => 1.0,
        }
    }

    /// Fraction of the dense size sent on the backward pass.
    pub fn backward_fraction(&self) -> f64 {
        match *self {
            SizeModel::SizeReduction { d, k }
            | SizeModel::Topk { d, k }
            | SizeModel::TopkLeb { d, k } => k as f64 / d as f64,
            SizeModel::Quant { .. } | SizeModel::L1 { .. } | SizeModel::Dense => 1.0,
        }
    }

    /// Round-trip fraction (forward + backward over 2x dense), the
    /// "compressed size" the paper reports for training traffic.
    pub fn roundtrip_fraction(&self) -> f64 {
        (self.forward_fraction() + self.backward_fraction()) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_compressed_sizes() {
        // CIFAR-100: d=128, k=3 -> 2.86% forward
        let m = SizeModel::topk(128, 3);
        assert!((m.forward_fraction() * 100.0 - 2.86).abs() < 0.01);
        // k=6 -> 5.71%, k=13 -> 12.38%
        assert!((SizeModel::topk(128, 6).forward_fraction() * 100.0 - 5.71).abs() < 0.01);
        assert!((SizeModel::topk(128, 13).forward_fraction() * 100.0 - 12.38).abs() < 0.01);
        // YooChoose: d=300, k=2 -> 0.85%, k=4 -> 1.71%, k=9 -> 3.84%
        assert!((SizeModel::topk(300, 2).forward_fraction() * 100.0 - 0.854).abs() < 0.01);
        assert!((SizeModel::topk(300, 9).forward_fraction() * 100.0 - 3.84).abs() < 0.01);
        // DBPedia: d=600, k=2 -> 0.44%
        assert!((SizeModel::topk(600, 2).forward_fraction() * 100.0 - 0.44).abs() < 0.01);
        // Tiny-ImageNet: d=1280, k=2 -> 0.21%
        assert!((SizeModel::topk(1280, 2).forward_fraction() * 100.0 - 0.21).abs() < 0.01);
    }

    #[test]
    fn quant_fraction() {
        assert!((SizeModel::quant(128, 2).forward_fraction() - 2.0 / 32.0).abs() < 1e-12);
        assert!((SizeModel::quant(128, 4).forward_fraction() - 4.0 / 32.0).abs() < 1e-12);
        assert_eq!(SizeModel::quant(128, 4).backward_fraction(), 1.0);
    }

    #[test]
    fn size_reduction_fraction() {
        let m = SizeModel::size_reduction(128, 4);
        assert!((m.forward_fraction() - 4.0 / 128.0).abs() < 1e-12);
        assert_eq!(m.forward_fraction(), m.backward_fraction());
    }

    #[test]
    fn topk_backward_has_no_index_cost() {
        let m = SizeModel::topk(128, 6);
        assert!(m.backward_fraction() < m.forward_fraction());
        assert!((m.backward_fraction() - 6.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn topk_leb_estimate_tracks_gap_width() {
        // d=600, k=14: mean gap 42 is one LEB128 byte -> overhead 8/32,
        // beating the 10-bit fixed layout's 10/32
        let leb = SizeModel::topk_leb(600, 14);
        let fixed = SizeModel::topk(600, 14);
        assert!(leb.forward_fraction() < fixed.forward_fraction());
        assert!((leb.forward_fraction() - 14.0 / 600.0 * 1.25).abs() < 1e-12);
        // d=1280, k=2: mean gap 640 needs two bytes -> worse than 11 bits
        let leb = SizeModel::topk_leb(1280, 2);
        let fixed = SizeModel::topk(1280, 2);
        assert!(leb.forward_fraction() > fixed.forward_fraction());
        // backward carries no indices either way
        assert_eq!(
            SizeModel::topk_leb(600, 14).backward_fraction(),
            SizeModel::topk(600, 14).backward_fraction()
        );
    }

    #[test]
    fn motivating_example_resnet20_iteration_cost() {
        // Paper §1: cut 32*32*32, batch 32, fwd+bwd f32 = 8 MiB/iteration.
        let cut = 32 * 32 * 32;
        let batch = 32;
        let bytes = 2 * 4 * batch * cut;
        assert_eq!(bytes, 8 * 1024 * 1024);
    }
}
