//! Telemetry-driven per-stream codec adaptation (the policy half of the
//! `Respec` renegotiation plane; the mechanism half lives in
//! `transport::mux`).
//!
//! The policy is a pure function from observed signals to a proposed
//! method: link telemetry (`LinkStats` throughput, injected-fault rate,
//! bytes parked under flow control) plus training signals (epoch, the
//! ledger's loss slope) pick the next k/bits for a stream. Deterministic
//! by construction — the same signals always propose the same spec — so
//! adaptive runs stay replayable under the chaos harness.
//!
//! Decisions walk a fixed ladder of candidate sizes one rung at a time
//! (hysteresis: no rung change, no proposal), trading the two failure
//! modes the paper's static specs cannot escape:
//!
//! - a struggling link (faults, congestion) wants FEWER bytes per step,
//!   so retransmits and queue delay stop dominating time-to-accuracy;
//! - a healthy link under a flattening loss wants MORE fidelity, since
//!   spare capacity is better spent on accuracy than saved.
//!
//! Every proposed switch — accepted or refused — is recorded in the
//! `RunLedger` (`record_switch`), so communication accounting stays
//! byte-exact and auditable across spec generations.

use crate::config::Method;
use crate::metrics::RunLedger;
use crate::transport::LinkStats;

/// Observed inputs to one adaptation decision.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdaptSignals {
    /// Training epoch the decision is made in.
    pub epoch: u32,
    /// d(train_loss)/d(epoch) over the last two ledger records; negative
    /// while the model is still learning, near zero on a plateau.
    pub loss_slope: f64,
    /// Framed goodput over the observation window, bytes/second.
    pub throughput: f64,
    /// Link faults per frame sent, in [0, 1].
    pub fault_rate: f64,
    /// Bytes parked under flow control (sent but not yet consumed).
    pub buffered_bytes: u64,
}

impl AdaptSignals {
    /// Derive the link-side signals from a stream's `LinkStats` delta over
    /// `secs` of (simulated or wall) time.
    pub fn from_link(stats: &LinkStats, secs: f64, buffered_bytes: u64) -> Self {
        let sent = stats.frames_sent.max(1);
        AdaptSignals {
            epoch: 0,
            loss_slope: 0.0,
            throughput: if secs > 0.0 { stats.total_bytes() as f64 / secs } else { 0.0 },
            fault_rate: (stats.faults.total() as f64 / sent as f64).min(1.0),
            buffered_bytes,
        }
    }

    /// Fill in the training-side signals from the run ledger.
    pub fn with_training(mut self, ledger: &RunLedger) -> Self {
        self.epoch = ledger.epochs.last().map(|e| e.epoch).unwrap_or(0);
        self.loss_slope = loss_slope(ledger);
        self
    }
}

/// d(train_loss)/d(epoch) between the ledger's last two records; 0 until
/// two epochs exist.
pub fn loss_slope(ledger: &RunLedger) -> f64 {
    match ledger.epochs.as_slice() {
        [.., a, b] => b.train_loss - a.train_loss,
        _ => 0.0,
    }
}

/// The adaptation policy: a ladder of candidate sparsity levels plus the
/// thresholds that move a stream along it.
#[derive(Clone, Debug)]
pub struct AdaptPolicy {
    /// Candidate k values, ascending (more k = more bytes, more fidelity).
    /// A stream moves at most one rung per decision.
    pub k_ladder: Vec<usize>,
    /// Fault rate above this marks the link lossy: step down the ladder.
    pub lossy_fault_rate: f64,
    /// Flow-control backlog above this marks congestion: step down.
    pub congested_bytes: u64,
    /// |loss slope| below this marks a plateau: step up (spend spare
    /// capacity on fidelity). Only consulted once an epoch has completed,
    /// so a cold start never reads as a plateau.
    pub plateau_slope: f64,
}

impl Default for AdaptPolicy {
    fn default() -> Self {
        AdaptPolicy {
            k_ladder: vec![2, 4, 6, 12],
            lossy_fault_rate: 0.05,
            congested_bytes: 64 * 1024,
            plateau_slope: 0.02,
        }
    }
}

impl AdaptPolicy {
    /// Propose the next method for a stream, or `None` to keep the
    /// current one (hysteresis: unchanged rung, or a method without a k
    /// to adapt). The proposal preserves the method family — a
    /// `RandTopk` stream keeps its alpha, a `Topk` stream stays `Topk` —
    /// only the k moves.
    pub fn decide(&self, current: Method, sig: &AdaptSignals) -> Option<Method> {
        let k = current.k()?;
        if self.k_ladder.is_empty() {
            return None;
        }
        // nearest rung to the current k (the current spec need not be on
        // the ladder at all — e.g. a hand-picked static k)
        let pos = self
            .k_ladder
            .iter()
            .enumerate()
            .min_by_key(|(_, v)| v.abs_diff(k))
            .map(|(i, _)| i)
            .unwrap();
        let target = if sig.fault_rate > self.lossy_fault_rate
            || sig.buffered_bytes > self.congested_bytes
        {
            // struggling link: cheaper frames beat fidelity
            pos.checked_sub(1)?
        } else if sig.epoch > 0 && sig.loss_slope.abs() < self.plateau_slope {
            // healthy link, flat loss: buy fidelity with the headroom
            (pos + 1).min(self.k_ladder.len() - 1)
        } else {
            pos
        };
        let next = self.k_ladder[target];
        (next != k).then(|| with_k(current, next))
    }
}

/// The same method family at a different k. Methods without a k come back
/// unchanged.
pub fn with_k(m: Method, k: usize) -> Method {
    match m {
        Method::RandTopk { alpha, .. } => Method::RandTopk { k, alpha },
        Method::Topk { .. } => Method::Topk { k },
        Method::SizeReduction { .. } => Method::SizeReduction { k },
        other => other,
    }
}

/// Scalar "level" of a method for the numeric-only ledger: k for the
/// sparse family, bits for quantization, 0 for dense.
pub fn method_level(m: Method) -> f64 {
    match m {
        Method::Quant { bits } => bits as f64,
        other => other.k().map(|k| k as f64).unwrap_or(0.0),
    }
}

/// Record one renegotiation (accepted or refused) in the run ledger, so
/// a run's spec history is auditable next to its byte counts. Keys:
/// `respec_events` counts proposals, `respec_accepted`/`respec_rejected`
/// split the verdicts, and each event `n` gets
/// `respec_{n:02}_{stream,step,from,to,accepted}` entries.
pub fn record_switch(
    ledger: &mut RunLedger,
    stream_id: u32,
    step: u64,
    from: Method,
    to: Method,
    accepted: bool,
) {
    let n = ledger.extra.get("respec_events").copied().unwrap_or(0.0) as u64;
    ledger.extra.insert("respec_events".into(), (n + 1) as f64);
    let verdict = if accepted { "respec_accepted" } else { "respec_rejected" };
    let v = ledger.extra.get(verdict).copied().unwrap_or(0.0);
    ledger.extra.insert(verdict.into(), v + 1.0);
    let key = |s: &str| format!("respec_{n:02}_{s}");
    ledger.extra.insert(key("stream"), stream_id as f64);
    ledger.extra.insert(key("step"), step as f64);
    ledger.extra.insert(key("from"), method_level(from));
    ledger.extra.insert(key("to"), method_level(to));
    ledger.extra.insert(key("accepted"), if accepted { 1.0 } else { 0.0 });
}

/// Accuracy per megabyte of framed communication — the figure of merit
/// `BENCH_adapt.json` compares adaptive against static specs on.
pub fn accuracy_per_mb(metric: f64, comm_bytes: u64) -> f64 {
    if comm_bytes == 0 {
        return 0.0;
    }
    metric / (comm_bytes as f64 / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochRecord;
    use crate::transport::FaultCounts;

    fn quiet() -> AdaptSignals {
        AdaptSignals { epoch: 0, loss_slope: -1.0, ..Default::default() }
    }

    #[test]
    fn lossy_link_steps_down_one_rung() {
        let p = AdaptPolicy::default();
        let sig = AdaptSignals { fault_rate: 0.2, ..quiet() };
        assert_eq!(p.decide(Method::Topk { k: 6 }, &sig), Some(Method::Topk { k: 4 }));
        // one rung at a time, never a cliff
        assert_eq!(p.decide(Method::Topk { k: 12 }, &sig), Some(Method::Topk { k: 6 }));
        // already at the bottom: nothing cheaper to propose
        assert_eq!(p.decide(Method::Topk { k: 2 }, &sig), None);
    }

    #[test]
    fn congestion_counts_as_struggle() {
        let p = AdaptPolicy::default();
        let sig = AdaptSignals { buffered_bytes: 1 << 20, ..quiet() };
        assert_eq!(p.decide(Method::Topk { k: 6 }, &sig), Some(Method::Topk { k: 4 }));
    }

    #[test]
    fn plateau_on_a_healthy_link_steps_up() {
        let p = AdaptPolicy::default();
        let sig = AdaptSignals { epoch: 3, loss_slope: -0.001, ..Default::default() };
        assert_eq!(p.decide(Method::Topk { k: 4 }, &sig), Some(Method::Topk { k: 6 }));
        // top of the ladder holds
        assert_eq!(p.decide(Method::Topk { k: 12 }, &sig), None);
        // epoch 0 never reads as a plateau (no slope evidence yet)
        let cold = AdaptSignals { epoch: 0, loss_slope: 0.0, ..Default::default() };
        assert_eq!(p.decide(Method::Topk { k: 4 }, &cold), None);
    }

    #[test]
    fn steady_state_and_non_k_methods_hold() {
        let p = AdaptPolicy::default();
        assert_eq!(p.decide(Method::Topk { k: 6 }, &quiet()), None);
        assert_eq!(p.decide(Method::Quant { bits: 2 }, &quiet()), None);
        assert_eq!(p.decide(Method::None, &quiet()), None);
        // off-ladder k snaps to the nearest rung before moving
        let lossy = AdaptSignals { fault_rate: 1.0, ..quiet() };
        assert_eq!(p.decide(Method::Topk { k: 7 }, &lossy), Some(Method::Topk { k: 4 }));
    }

    #[test]
    fn family_is_preserved_across_a_switch() {
        let p = AdaptPolicy::default();
        let sig = AdaptSignals { fault_rate: 0.2, ..quiet() };
        assert_eq!(
            p.decide(Method::RandTopk { k: 6, alpha: 0.1 }, &sig),
            Some(Method::RandTopk { k: 4, alpha: 0.1 })
        );
    }

    #[test]
    fn signals_derive_from_link_and_ledger() {
        let stats = LinkStats {
            frames_sent: 100,
            bytes_sent: 5_000,
            bytes_recv: 5_000,
            faults: FaultCounts { dropped: 10, ..Default::default() },
            ..Default::default()
        };
        let sig = AdaptSignals::from_link(&stats, 2.0, 7);
        assert_eq!(sig.throughput, 5_000.0);
        assert!((sig.fault_rate - 0.1).abs() < 1e-12);
        assert_eq!(sig.buffered_bytes, 7);

        let mut ledger = RunLedger::default();
        assert_eq!(loss_slope(&ledger), 0.0);
        ledger.push(EpochRecord { epoch: 0, train_loss: 2.0, ..Default::default() });
        ledger.push(EpochRecord { epoch: 1, train_loss: 1.5, ..Default::default() });
        let sig = sig.with_training(&ledger);
        assert_eq!(sig.epoch, 1);
        assert!((sig.loss_slope + 0.5).abs() < 1e-12);
    }

    #[test]
    fn ledger_records_every_switch() {
        let mut ledger = RunLedger::default();
        record_switch(&mut ledger, 1, 12, Method::Topk { k: 6 }, Method::Topk { k: 2 }, true);
        record_switch(&mut ledger, 3, 20, Method::Topk { k: 2 }, Method::Topk { k: 6 }, false);
        assert_eq!(ledger.extra.get("respec_events"), Some(&2.0));
        assert_eq!(ledger.extra.get("respec_accepted"), Some(&1.0));
        assert_eq!(ledger.extra.get("respec_rejected"), Some(&1.0));
        assert_eq!(ledger.extra.get("respec_00_stream"), Some(&1.0));
        assert_eq!(ledger.extra.get("respec_00_step"), Some(&12.0));
        assert_eq!(ledger.extra.get("respec_00_from"), Some(&6.0));
        assert_eq!(ledger.extra.get("respec_00_to"), Some(&2.0));
        assert_eq!(ledger.extra.get("respec_01_accepted"), Some(&0.0));
    }

    #[test]
    fn accuracy_per_mb_is_metric_over_megabytes() {
        assert_eq!(accuracy_per_mb(0.8, 2_000_000), 0.4);
        assert_eq!(accuracy_per_mb(0.8, 0), 0.0);
    }
}
