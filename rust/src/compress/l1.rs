//! L1-regularization codec: variable-k sparse encoding of a dense batch.
//!
//! The sparsity is *induced by training* (the loss carries λ·Σ|o_i|, in
//! the dense top_fwdbwd artifact); the feature owner then ships only the
//! entries with |o| > eps. The per-input compressed size therefore varies —
//! exactly the paper's point about L1 being hard to control (§3.3). The
//! backward pass is dense (Table 2).

use anyhow::{bail, Result};

use crate::util::{index_bits, BitReader, BitWriter};

use super::{DenseBatch, Payload};

#[derive(Clone, Copy, Debug)]
pub struct L1Codec {
    pub dim: usize,
    /// Magnitude threshold below which an activation counts as zero.
    pub eps: f32,
}

impl L1Codec {
    pub fn new(dim: usize, eps: f32) -> Self {
        L1Codec { dim, eps }
    }

    /// Wire layout: per row [count u16][count * f32 values]; then all
    /// rows' indices bit-packed at ⌈log2 d⌉ bits.
    pub fn encode(&self, batch: &DenseBatch) -> Result<Payload> {
        if batch.dim != self.dim {
            bail!("l1 codec d={} fed batch d={}", self.dim, batch.dim);
        }
        if self.dim > u16::MAX as usize {
            bail!("l1 codec supports d <= 65535");
        }
        let nbits = index_bits(self.dim);
        let mut bytes = Vec::new();
        let mut w = BitWriter::new();
        for r in 0..batch.rows {
            let row = batch.row(r);
            let nz: Vec<usize> = (0..self.dim).filter(|&j| row[j].abs() > self.eps).collect();
            bytes.extend_from_slice(&(nz.len() as u16).to_le_bytes());
            for &j in &nz {
                bytes.extend_from_slice(&row[j].to_le_bytes());
                w.write(j as u64, nbits);
            }
        }
        bytes.extend_from_slice(&w.into_bytes());
        Ok(Payload::VarSparse { rows: batch.rows, dim: self.dim, bytes })
    }

    pub fn decode(&self, payload: &Payload) -> Result<DenseBatch> {
        let Payload::VarSparse { rows, dim, bytes } = payload else {
            bail!("payload is not var-sparse");
        };
        if *dim != self.dim {
            bail!("l1 payload geometry mismatch");
        }
        // first scan: counts + values section
        let mut counts = Vec::with_capacity(*rows);
        let mut values: Vec<Vec<f32>> = Vec::with_capacity(*rows);
        let mut pos = 0usize;
        for _ in 0..*rows {
            if pos + 2 > bytes.len() {
                bail!("l1 payload truncated counts");
            }
            let c = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as usize;
            pos += 2;
            if c > self.dim {
                bail!("l1 row count {c} > d");
            }
            if pos + 4 * c > bytes.len() {
                bail!("l1 payload truncated values");
            }
            let vals = bytes[pos..pos + 4 * c]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            pos += 4 * c;
            counts.push(c);
            values.push(vals);
        }
        let nbits = index_bits(self.dim);
        let mut reader = BitReader::new(&bytes[pos..]);
        let mut out = DenseBatch::zeros(*rows, self.dim);
        for r in 0..*rows {
            for v in &values[r] {
                let Some(j) = reader.read(nbits) else {
                    bail!("l1 payload truncated indices");
                };
                let j = j as usize;
                if j >= self.dim {
                    bail!("l1 decoded index {j} out of range");
                }
                out.data[r * self.dim + j] = *v;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sparse_dense(rng: &mut Rng, rows: usize, dim: usize, density: f32) -> DenseBatch {
        let data = (0..rows * dim)
            .map(|_| {
                if rng.next_f32() < density {
                    rng.normal() + 0.5 // keep well above eps
                } else {
                    0.0
                }
            })
            .collect();
        DenseBatch::new(rows, dim, data)
    }

    #[test]
    fn roundtrip_preserves_above_eps() {
        let mut rng = Rng::new(1);
        let codec = L1Codec::new(600, 1e-6);
        let batch = sparse_dense(&mut rng, 16, 600, 0.05);
        let p = codec.encode(&batch).unwrap();
        let back = codec.decode(&p).unwrap();
        assert_eq!(back, batch);
    }

    #[test]
    fn thresholding_zeroes_small_entries() {
        let codec = L1Codec::new(4, 0.1);
        let batch = DenseBatch::new(1, 4, vec![0.05, -0.5, 0.0, 0.2]);
        let p = codec.encode(&batch).unwrap();
        let back = codec.decode(&p).unwrap();
        assert_eq!(back.row(0), &[0.0, -0.5, 0.0, 0.2]);
    }

    #[test]
    fn size_scales_with_density() {
        let mut rng = Rng::new(2);
        let codec = L1Codec::new(512, 1e-6);
        let p1 = codec.encode(&sparse_dense(&mut rng, 32, 512, 0.02)).unwrap();
        let p2 = codec.encode(&sparse_dense(&mut rng, 32, 512, 0.2)).unwrap();
        assert!(p2.wire_bytes() > 5 * p1.wire_bytes());
    }

    #[test]
    fn empty_rows_ok() {
        let codec = L1Codec::new(32, 1e-6);
        let batch = DenseBatch::zeros(4, 32);
        let p = codec.encode(&batch).unwrap();
        // 4 rows * 2-byte count only
        assert_eq!(p.wire_bytes(), 8);
        assert_eq!(codec.decode(&p).unwrap(), batch);
    }

    #[test]
    fn truncated_rejected() {
        let mut rng = Rng::new(3);
        let codec = L1Codec::new(64, 1e-6);
        let p = codec.encode(&sparse_dense(&mut rng, 4, 64, 0.3)).unwrap();
        if let Payload::VarSparse { rows, dim, bytes } = p {
            let cut = Payload::VarSparse { rows, dim, bytes: bytes[..6].to_vec() };
            assert!(codec.decode(&cut).is_err());
        }
    }
}
