//! L1-regularization codec: variable-k sparse encoding of a dense batch.
//!
//! The sparsity is *induced by training* (the loss carries λ·Σ|o_i|, in
//! the dense top_fwdbwd artifact); the feature owner then ships only the
//! entries with |o| > eps. The per-input compressed size therefore varies —
//! exactly the paper's point about L1 being hard to control (§3.3), and
//! why `expected_wire_bytes` is `None` for the forward pass. The backward
//! pass is dense (Table 2).

use anyhow::{bail, Result};

use crate::util::{index_bits, BitPacker, BitReader};

use super::codec::scratch_f32;
use super::{Batch, Codec, DenseBatch, DenseCodec, Pass, Payload, PayloadMeta, SizeModel};

#[derive(Clone, Copy, Debug)]
pub struct L1Codec {
    pub dim: usize,
    /// Magnitude threshold below which an activation counts as zero.
    pub eps: f32,
}

impl L1Codec {
    pub fn new(dim: usize, eps: f32) -> Self {
        L1Codec { dim, eps }
    }
}

impl Codec for L1Codec {
    fn name(&self) -> &'static str {
        "l1"
    }

    fn size_model(&self) -> SizeModel {
        // forward size is emergent (k_mean only known after measuring);
        // the backward fraction (dense = 1) is what this model pins
        SizeModel::L1 { d: self.dim, k_mean: 0.0 }
    }

    fn meta(&self, rows: usize, pass: Pass) -> PayloadMeta {
        match pass {
            Pass::Forward => PayloadMeta::VarSparse { rows, dim: self.dim },
            Pass::Backward => PayloadMeta::Dense { rows, dim: self.dim },
        }
    }

    fn expected_wire_bytes(&self, rows: usize, pass: Pass) -> Option<usize> {
        match pass {
            // input-dependent: depends on how many entries exceed eps
            Pass::Forward => None,
            Pass::Backward => Some(rows * self.dim * 4),
        }
    }

    /// Forward wire layout: per row [count u16][count * f32 values]; then
    /// all rows' indices bit-packed at ⌈log2 d⌉ bits.
    fn encode_into(&self, batch: &Batch, pass: Pass, out: &mut Vec<u8>) -> Result<()> {
        // Table 2: the gradient travels dense — delegate to the one
        // implementation of the dense wire layout
        if pass == Pass::Backward {
            return DenseCodec::new(self.dim).encode_into(batch, pass, out);
        }
        let Batch::Dense(batch) = batch else {
            bail!("l1 codec fed a non-dense batch");
        };
        if batch.dim != self.dim {
            bail!("l1 codec d={} fed batch d={}", self.dim, batch.dim);
        }
        if self.dim > u16::MAX as usize {
            bail!("l1 codec supports d <= 65535");
        }
        let nbits = index_bits(self.dim);
        // two scans over the batch: counts + values first, then the
        // trailing index section packed straight into `out` — no per-row
        // index scratch, and the layout matches the single-pass original
        for r in 0..batch.rows {
            let row = batch.row(r);
            let count = row.iter().filter(|v| v.abs() > self.eps).count();
            out.extend_from_slice(&(count as u16).to_le_bytes());
            for v in row {
                if v.abs() > self.eps {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        let mut w = BitPacker::new(out);
        for r in 0..batch.rows {
            for (j, v) in batch.row(r).iter().enumerate() {
                if v.abs() > self.eps {
                    w.write(j as u64, nbits);
                }
            }
        }
        w.finish();
        Ok(())
    }

    fn decode_into(&self, payload: &Payload, pass: Pass, out: &mut Option<Batch>) -> Result<()> {
        match pass {
            Pass::Forward => {
                let mut data = scratch_f32(out);
                let PayloadMeta::VarSparse { rows, dim } = payload.meta else {
                    bail!("payload is not var-sparse");
                };
                if dim != self.dim {
                    bail!("l1 payload geometry mismatch");
                }
                let bytes = &payload.bytes;
                // cheap upfront bound before sizing any allocation by the
                // wire-supplied `rows`: every row costs at least its 2-byte
                // count, so a huge claimed row count cannot force a huge
                // Vec reservation off a tiny frame
                if bytes.len() < rows * 2 {
                    bail!("l1 payload truncated counts");
                }
                // first scan: validate the counts + values sections and
                // total the nonzeros, touching no scratch
                let mut total_nz = 0usize;
                let mut pos = 0usize;
                for _ in 0..rows {
                    if pos + 2 > bytes.len() {
                        bail!("l1 payload truncated counts");
                    }
                    let c = u16::from_le_bytes([bytes[pos], bytes[pos + 1]]) as usize;
                    pos += 2;
                    if c > self.dim {
                        bail!("l1 row count {c} > d");
                    }
                    if pos + 4 * c > bytes.len() {
                        bail!("l1 payload truncated values");
                    }
                    pos += 4 * c;
                    total_nz += c;
                }
                let nbits = index_bits(self.dim);
                // exact-length contract: the index section is the remainder
                let index_bytes = (total_nz * nbits as usize).div_ceil(8);
                if bytes.len() != pos + index_bytes {
                    bail!(
                        "l1 payload wrong length: {} != {}",
                        bytes.len(),
                        pos + index_bytes
                    );
                }
                // second scan: walk values and packed indices in lockstep,
                // scattering straight into the zeroed dense scratch
                let mut reader = BitReader::new(&bytes[pos..]);
                data.resize(rows * self.dim, 0.0);
                let mut vpos = 0usize;
                for r in 0..rows {
                    let c = u16::from_le_bytes([bytes[vpos], bytes[vpos + 1]]) as usize;
                    vpos += 2;
                    for _ in 0..c {
                        let v = f32::from_le_bytes(bytes[vpos..vpos + 4].try_into().unwrap());
                        vpos += 4;
                        let Some(j) = reader.read(nbits) else {
                            bail!("l1 payload truncated indices");
                        };
                        let j = j as usize;
                        if j >= self.dim {
                            bail!("l1 decoded index {j} out of range");
                        }
                        data[r * self.dim + j] = v;
                    }
                }
                *out = Some(Batch::Dense(DenseBatch::new(rows, self.dim, data)));
                Ok(())
            }
            Pass::Backward => DenseCodec::new(self.dim).decode_into(payload, pass, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sparse_dense(rng: &mut Rng, rows: usize, dim: usize, density: f32) -> DenseBatch {
        let data = (0..rows * dim)
            .map(|_| {
                if rng.next_f32() < density {
                    rng.normal() + 0.5 // keep well above eps
                } else {
                    0.0
                }
            })
            .collect();
        DenseBatch::new(rows, dim, data)
    }

    #[test]
    fn roundtrip_preserves_above_eps() {
        let mut rng = Rng::new(1);
        let codec = L1Codec::new(600, 1e-6);
        let batch = Batch::Dense(sparse_dense(&mut rng, 16, 600, 0.05));
        let p = codec.encode(&batch, Pass::Forward).unwrap();
        let back = codec.decode(&p, Pass::Forward).unwrap();
        assert_eq!(back, batch);
    }

    #[test]
    fn thresholding_zeroes_small_entries() {
        let codec = L1Codec::new(4, 0.1);
        let batch = Batch::Dense(DenseBatch::new(1, 4, vec![0.05, -0.5, 0.0, 0.2]));
        let p = codec.encode(&batch, Pass::Forward).unwrap();
        let Batch::Dense(back) = codec.decode(&p, Pass::Forward).unwrap() else {
            panic!("expected dense batch");
        };
        assert_eq!(back.row(0), &[0.0, -0.5, 0.0, 0.2]);
    }

    #[test]
    fn size_scales_with_density() {
        let mut rng = Rng::new(2);
        let codec = L1Codec::new(512, 1e-6);
        let p1 = codec
            .encode(&Batch::Dense(sparse_dense(&mut rng, 32, 512, 0.02)), Pass::Forward)
            .unwrap();
        let p2 = codec
            .encode(&Batch::Dense(sparse_dense(&mut rng, 32, 512, 0.2)), Pass::Forward)
            .unwrap();
        assert!(p2.wire_bytes() > 5 * p1.wire_bytes());
        // the forward size is emergent — the codec cannot predict it
        assert_eq!(codec.expected_wire_bytes(32, Pass::Forward), None);
    }

    #[test]
    fn backward_pass_is_dense() {
        let mut rng = Rng::new(7);
        let codec = L1Codec::new(32, 1e-4);
        let dense = DenseBatch::new(4, 32, (0..128).map(|_| rng.normal()).collect());
        let p = codec.encode(&Batch::Dense(dense.clone()), Pass::Backward).unwrap();
        assert_eq!(p.wire_bytes(), 4 * 32 * 4);
        assert_eq!(codec.expected_wire_bytes(4, Pass::Backward), Some(4 * 32 * 4));
        // backward does NOT threshold: the gradient arrives exactly
        assert_eq!(codec.decode(&p, Pass::Backward).unwrap(), Batch::Dense(dense));
    }

    #[test]
    fn empty_rows_ok() {
        let codec = L1Codec::new(32, 1e-6);
        let batch = Batch::Dense(DenseBatch::zeros(4, 32));
        let p = codec.encode(&batch, Pass::Forward).unwrap();
        // 4 rows * 2-byte count only
        assert_eq!(p.wire_bytes(), 8);
        assert_eq!(codec.decode(&p, Pass::Forward).unwrap(), batch);
    }

    #[test]
    fn truncated_rejected() {
        let mut rng = Rng::new(3);
        let codec = L1Codec::new(64, 1e-6);
        let p = codec
            .encode(&Batch::Dense(sparse_dense(&mut rng, 4, 64, 0.3)), Pass::Forward)
            .unwrap();
        let cut = Payload::new(p.meta, p.bytes[..6].to_vec());
        assert!(codec.decode(&cut, Pass::Forward).is_err());
    }
}
