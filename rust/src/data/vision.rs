//! SynthVision: n-class synthetic images (CIFAR-100 / Tiny-ImageNet
//! analogs). Each class owns a low-frequency "texture" prototype (sum of
//! random 2-D sinusoids per channel) so that convolutional features — not
//! raw pixels — separate the classes; samples add noise and undergo the
//! paper's augmentations (random crop with reflection padding + horizontal
//! flip).

use crate::util::Rng;

use super::{Dataset, Split};

const WAVES: usize = 4;
const PAD: usize = 3;

struct ClassPattern {
    /// per channel, WAVES x (fx, fy, phase, amp)
    waves: Vec<[f32; 4]>,
}

pub struct SynthVision {
    n_classes: usize,
    size: usize,
    n_train: usize,
    n_test: usize,
    patterns: Vec<ClassPattern>,
    seed: u64,
    noise: f32,
}

impl SynthVision {
    pub fn new(n_classes: usize, size: usize, seed: u64, n_train: usize, n_test: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EE1_D000);
        let patterns = (0..n_classes)
            .map(|_| ClassPattern {
                waves: (0..3 * WAVES)
                    .map(|_| {
                        [
                            0.15 + 0.85 * rng.next_f32(), // fx (cycles / 8 px)
                            0.15 + 0.85 * rng.next_f32(), // fy
                            rng.next_f32() * std::f32::consts::TAU,
                            0.4 + 0.6 * rng.next_f32(),
                        ]
                    })
                    .collect(),
            })
            .collect();
        SynthVision { n_classes, size, n_train, n_test, patterns, seed, noise: 0.35 }
    }

    fn prototype_pixel(&self, label: usize, c: usize, x: f32, yy: f32) -> f32 {
        let p = &self.patterns[label];
        let mut v = 0.0;
        for w in &p.waves[c * WAVES..(c + 1) * WAVES] {
            v += w[3] * (w[0] * x * 0.8 + w[1] * yy * 0.8 + w[2]).sin();
        }
        v / (WAVES as f32).sqrt()
    }
}

impl Dataset for SynthVision {
    fn name(&self) -> &str {
        "synth-vision"
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn feature_shape(&self) -> (Vec<usize>, bool) {
        (vec![self.size, self.size, 3], false)
    }

    fn sample(&self, split: Split, index: usize, augment: bool) -> (Vec<f32>, Vec<i32>, i32) {
        let tag = match split {
            Split::Train => 0x11u64,
            Split::Test => 0x22u64,
        };
        let mut rng = Rng::new(self.seed ^ (tag << 56) ^ (index as u64).wrapping_mul(0x9E37));
        let label = rng.below(self.n_classes);
        let s = self.size;
        // augmentation: shift in [-PAD, PAD], optional horizontal flip
        let (dx, dy, flip) = if augment {
            (
                rng.below(2 * PAD + 1) as i32 - PAD as i32,
                rng.below(2 * PAD + 1) as i32 - PAD as i32,
                rng.next_f32() < 0.5,
            )
        } else {
            (0, 0, false)
        };
        let mut img = vec![0.0f32; s * s * 3];
        for y in 0..s {
            for x in 0..s {
                // reflection at borders after shift
                let sx0 = x as i32 + dx;
                let sy0 = y as i32 + dy;
                let sx = sx0.rem_euclid(2 * s as i32 - 2);
                let sy = sy0.rem_euclid(2 * s as i32 - 2);
                let sx = if sx >= s as i32 { 2 * (s as i32 - 1) - sx } else { sx } as f32;
                let sy = if sy >= s as i32 { 2 * (s as i32 - 1) - sy } else { sy } as f32;
                let sx = if flip { (s - 1) as f32 - sx } else { sx };
                for c in 0..3 {
                    let v = self.prototype_pixel(label, c, sx, sy) + self.noise * rng.normal();
                    img[(y * s + x) * 3 + c] = v;
                }
            }
        }
        (img, vec![], label as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_without_augment() {
        let d = SynthVision::new(100, 32, 42, 128, 64);
        assert_eq!(d.sample(Split::Test, 3, false), d.sample(Split::Test, 3, false));
    }

    #[test]
    fn augmentation_changes_pixels_not_label() {
        let d = SynthVision::new(100, 32, 42, 128, 64);
        let (x1, _, y1) = d.sample(Split::Train, 3, false);
        let (x2, _, y2) = d.sample(Split::Train, 3, true);
        assert_eq!(y1, y2);
        assert_ne!(x1, x2);
    }

    #[test]
    fn batch_shape_nhwc() {
        let d = SynthVision::new(100, 32, 42, 128, 64);
        let b = d.batch(Split::Train, &[0, 1], true);
        assert_eq!(b.x.shape(), &[2, 32, 32, 3]);
    }

    #[test]
    fn class_prototypes_distinguishable() {
        // mean pixel correlation between two samples of the same class must
        // beat two samples of different classes
        let d = SynthVision::new(10, 32, 7, 512, 64);
        let mut same = 0.0;
        let mut diff = 0.0;
        let mut n_same = 0;
        let mut n_diff = 0;
        let samples: Vec<_> = (0..40).map(|i| d.sample(Split::Train, i, false)).collect();
        for i in 0..40 {
            for j in (i + 1)..40 {
                let dot: f32 = samples[i]
                    .0
                    .iter()
                    .zip(&samples[j].0)
                    .map(|(a, b)| a * b)
                    .sum();
                if samples[i].2 == samples[j].2 {
                    same += dot;
                    n_same += 1;
                } else {
                    diff += dot;
                    n_diff += 1;
                }
            }
        }
        assert!(n_same > 0 && n_diff > 0);
        assert!(same / n_same as f32 > diff / n_diff as f32 + 10.0);
    }

    #[test]
    fn values_bounded() {
        let d = SynthVision::new(100, 32, 42, 128, 64);
        let (x, _, _) = d.sample(Split::Train, 0, true);
        assert!(x.iter().all(|v| v.abs() < 10.0));
    }
}
