//! SynthSession: Markov-chain item sessions (YooChoose / GRU4Rec analog).
//!
//! The item catalog carries a sparse first-order transition structure:
//! every item has a small successor set with skewed weights, so a
//! recurrent model can learn next-item prediction well above chance while
//! hit-ratio@20 stays far from 1 (as in the paper). Item popularity is
//! Zipf-ish, matching session-log statistics.

use crate::util::Rng;

use super::{Dataset, Split};

const SUCCESSORS: usize = 8;

pub struct SynthSession {
    n_items: usize,
    seq_len: usize,
    n_train: usize,
    n_test: usize,
    /// n_items x SUCCESSORS successor ids
    succ: Vec<u32>,
    /// SUCCESSORS skewed weights (shared)
    weights: [f32; SUCCESSORS],
    seed: u64,
}

impl SynthSession {
    pub fn new(n_items: usize, seq_len: usize, seed: u64, n_train: usize, n_test: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x5E55_1000);
        let mut succ = Vec::with_capacity(n_items * SUCCESSORS);
        for _ in 0..n_items {
            for _ in 0..SUCCESSORS {
                succ.push(rng.below(n_items) as u32);
            }
        }
        // geometric-ish weights: p_i ∝ 0.6^i
        let mut weights = [0.0f32; SUCCESSORS];
        let mut w = 1.0f32;
        for slot in weights.iter_mut() {
            *slot = w;
            w *= 0.6;
        }
        SynthSession { n_items, seq_len, n_train, n_test, succ, weights, seed }
    }

    fn next_item(&self, cur: usize, rng: &mut Rng) -> usize {
        // 10% exploration to arbitrary items (session noise)
        if rng.next_f32() < 0.10 {
            return rng.below(self.n_items);
        }
        let slot = rng.weighted(&self.weights);
        self.succ[cur * SUCCESSORS + slot] as usize
    }
}

impl Dataset for SynthSession {
    fn name(&self) -> &str {
        "synth-session"
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn feature_shape(&self) -> (Vec<usize>, bool) {
        (vec![self.seq_len], true)
    }

    fn sample(&self, split: Split, index: usize, _augment: bool) -> (Vec<f32>, Vec<i32>, i32) {
        let tag = match split {
            Split::Train => 0x11u64,
            Split::Test => 0x22u64,
        };
        let mut rng = Rng::new(self.seed ^ (tag << 56) ^ (index as u64).wrapping_mul(0x517C));
        // Zipf-ish session start: favor low item ids
        let u = rng.next_f32();
        let mut cur = ((u * u) * self.n_items as f32) as usize % self.n_items;
        let mut seq = Vec::with_capacity(self.seq_len);
        for _ in 0..self.seq_len {
            seq.push(cur as i32);
            cur = self.next_item(cur, &mut rng);
        }
        // label = the true next item after the observed prefix
        (vec![], seq, cur as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d = SynthSession::new(2000, 16, 42, 128, 64);
        assert_eq!(d.sample(Split::Train, 9, false), d.sample(Split::Train, 9, false));
    }

    #[test]
    fn items_in_range() {
        let d = SynthSession::new(2000, 16, 42, 512, 64);
        for i in 0..200 {
            let (_, seq, y) = d.sample(Split::Train, i, false);
            assert_eq!(seq.len(), 16);
            assert!(seq.iter().all(|&it| (0..2000).contains(&it)));
            assert!((0..2000).contains(&y));
        }
    }

    #[test]
    fn transitions_predictable_above_chance() {
        // oracle that knows the transition table should hit@SUCCESSORS the
        // label most of the time (90% markov / 10% noise)
        let d = SynthSession::new(500, 16, 7, 2048, 64);
        let mut hits = 0;
        let n = 500;
        for i in 0..n {
            let (_, seq, y) = d.sample(Split::Train, i, false);
            let last = *seq.last().unwrap() as usize;
            let cands = &d.succ[last * SUCCESSORS..(last + 1) * SUCCESSORS];
            if cands.contains(&(y as u32)) {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!(rate > 0.80, "oracle hit rate {rate}");
    }

    #[test]
    fn batch_is_i32() {
        let d = SynthSession::new(2000, 16, 42, 128, 64);
        let b = d.batch(Split::Train, &[0, 1, 2], false);
        assert_eq!(b.x.shape(), &[3, 16]);
        assert!(b.x.as_i32().is_ok());
    }
}
