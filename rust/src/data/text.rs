//! SynthText: class-conditional token sequences (DBPedia / TextCNN analog).
//!
//! Each class owns a keyword set and a few class-specific bigrams; a
//! sample interleaves common filler tokens with keywords and bigrams.
//! Keywords make the task solvable by pooled unigram features, bigrams
//! reward the width-2+ convolutions — mirroring what TextCNN exploits in
//! real topic classification.

use crate::util::Rng;

use super::{Dataset, Split};

const KEYWORDS: usize = 12;
const BIGRAMS: usize = 4;
const COMMON_POOL: usize = 500;

pub struct SynthText {
    n_classes: usize,
    vocab: usize,
    seq_len: usize,
    n_train: usize,
    n_test: usize,
    /// n_classes * KEYWORDS
    keywords: Vec<u32>,
    /// n_classes * BIGRAMS * 2
    bigrams: Vec<u32>,
    seed: u64,
}

impl SynthText {
    pub fn new(
        n_classes: usize,
        vocab: usize,
        seq_len: usize,
        seed: u64,
        n_train: usize,
        n_test: usize,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x7E87_0000);
        // keywords drawn from the non-common part of the vocabulary
        let kw_pool = vocab - COMMON_POOL;
        let keywords = (0..n_classes * KEYWORDS)
            .map(|_| (COMMON_POOL + rng.below(kw_pool)) as u32)
            .collect();
        let bigrams = (0..n_classes * BIGRAMS * 2)
            .map(|_| (COMMON_POOL + rng.below(kw_pool)) as u32)
            .collect();
        SynthText { n_classes, vocab, seq_len, n_train, n_test, keywords, bigrams, seed }
    }
}

impl Dataset for SynthText {
    fn name(&self) -> &str {
        "synth-text"
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn feature_shape(&self) -> (Vec<usize>, bool) {
        (vec![self.seq_len], true)
    }

    fn sample(&self, split: Split, index: usize, _augment: bool) -> (Vec<f32>, Vec<i32>, i32) {
        let tag = match split {
            Split::Train => 0x11u64,
            Split::Test => 0x22u64,
        };
        let mut rng = Rng::new(self.seed ^ (tag << 56) ^ (index as u64).wrapping_mul(0xBEEF));
        let label = rng.below(self.n_classes);
        let kws = &self.keywords[label * KEYWORDS..(label + 1) * KEYWORDS];
        let bgs = &self.bigrams[label * BIGRAMS * 2..(label + 1) * BIGRAMS * 2];
        let mut seq = Vec::with_capacity(self.seq_len);
        while seq.len() < self.seq_len {
            let r = rng.next_f32();
            if r < 0.25 {
                seq.push(kws[rng.below(KEYWORDS)] as i32);
            } else if r < 0.35 && seq.len() + 2 <= self.seq_len {
                let b = rng.below(BIGRAMS);
                seq.push(bgs[b * 2] as i32);
                seq.push(bgs[b * 2 + 1] as i32);
            } else {
                seq.push(rng.below(COMMON_POOL) as i32);
            }
        }
        (vec![], seq, label as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d = SynthText::new(219, 5000, 32, 42, 128, 64);
        assert_eq!(d.sample(Split::Test, 4, false), d.sample(Split::Test, 4, false));
    }

    #[test]
    fn tokens_in_vocab() {
        let d = SynthText::new(219, 5000, 32, 42, 512, 64);
        for i in 0..100 {
            let (_, seq, y) = d.sample(Split::Train, i, false);
            assert_eq!(seq.len(), 32);
            assert!(seq.iter().all(|&t| (0..5000).contains(&t)));
            assert!((0..219).contains(&y));
        }
    }

    #[test]
    fn keywords_identify_class() {
        // bag-of-keywords oracle: count matches against each class's set
        let d = SynthText::new(50, 5000, 32, 7, 1024, 64);
        let mut correct = 0;
        let n = 300;
        for i in 0..n {
            let (_, seq, y) = d.sample(Split::Train, i, false);
            let mut best = (0usize, 0usize);
            for c in 0..50 {
                let kws = &d.keywords[c * KEYWORDS..(c + 1) * KEYWORDS];
                let hits = seq.iter().filter(|&&t| kws.contains(&(t as u32))).count();
                if hits > best.0 {
                    best = (hits, c);
                }
            }
            if best.1 as i32 == y {
                correct += 1;
            }
        }
        assert!(correct as f64 / n as f64 > 0.9, "oracle acc {correct}/{n}");
    }
}
