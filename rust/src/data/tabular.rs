//! SynthTabular: n-class gaussian mixture in feature space (quickstart /
//! MLP task). Class prototypes are well separated; within-class noise and
//! a shared nuisance subspace keep the task non-trivial.

use crate::util::Rng;

use super::{Dataset, Split};

pub struct SynthTabular {
    n_classes: usize,
    dim: usize,
    n_train: usize,
    n_test: usize,
    protos: Vec<f32>, // n_classes * dim
    seed: u64,
    noise: f32,
}

impl SynthTabular {
    pub fn new(n_classes: usize, dim: usize, seed: u64, n_train: usize, n_test: usize) -> Self {
        // Noise is tuned so the Bayes-ish accuracy sits around 70-80% for
        // n=100: a saturated task (100% for every method) cannot order the
        // compression methods as Table 3 requires.
        let mut rng = Rng::new(seed ^ 0x7AB1_E000);
        let protos = (0..n_classes * dim).map(|_| rng.normal()).collect();
        SynthTabular { n_classes, dim, n_train, n_test, protos, seed, noise: 2.8 }
    }
}

impl Dataset for SynthTabular {
    fn name(&self) -> &str {
        "synth-tabular"
    }

    fn len(&self, split: Split) -> usize {
        match split {
            Split::Train => self.n_train,
            Split::Test => self.n_test,
        }
    }

    fn feature_shape(&self) -> (Vec<usize>, bool) {
        (vec![self.dim], false)
    }

    fn sample(&self, split: Split, index: usize, _augment: bool) -> (Vec<f32>, Vec<i32>, i32) {
        let tag = match split {
            Split::Train => 0x11u64,
            Split::Test => 0x22u64,
        };
        let mut rng = Rng::new(self.seed ^ (tag << 56) ^ index as u64);
        let label = rng.below(self.n_classes);
        let proto = &self.protos[label * self.dim..(label + 1) * self.dim];
        let x = proto.iter().map(|&p| p + self.noise * rng.normal()).collect();
        (x, vec![], label as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let d = SynthTabular::new(100, 64, 42, 128, 64);
        let a = d.sample(Split::Train, 5, false);
        let b = d.sample(Split::Train, 5, false);
        assert_eq!(a, b);
        let c = d.sample(Split::Train, 6, false);
        assert_ne!(a.0, c.0);
    }

    #[test]
    fn train_test_disjoint_streams() {
        let d = SynthTabular::new(100, 64, 42, 128, 64);
        assert_ne!(
            d.sample(Split::Train, 3, false).0,
            d.sample(Split::Test, 3, false).0
        );
    }

    #[test]
    fn labels_in_range_and_diverse() {
        let d = SynthTabular::new(100, 64, 42, 2048, 64);
        let mut seen = std::collections::HashSet::new();
        for i in 0..2048 {
            let (_, _, y) = d.sample(Split::Train, i, false);
            assert!((0..100).contains(&y));
            seen.insert(y);
        }
        assert!(seen.len() > 90, "only {} classes seen", seen.len());
    }

    #[test]
    fn nearest_prototype_is_own_class() {
        // the generator must be learnable: nearest-centroid should beat
        // chance by a wide margin
        let d = SynthTabular::new(20, 64, 7, 512, 64);
        let mut correct = 0;
        for i in 0..200 {
            let (x, _, y) = d.sample(Split::Test, i, false);
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..20 {
                let p = &d.protos[c * 64..(c + 1) * 64];
                let dist: f32 = x.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as i32 == y {
                correct += 1;
            }
        }
        assert!(correct > 150, "nearest-centroid acc {correct}/200");
    }

    #[test]
    fn batch_shape() {
        let d = SynthTabular::new(100, 64, 42, 128, 64);
        let b = d.batch(Split::Train, &[0, 1, 2, 3], false);
        assert_eq!(b.x.shape(), &[4, 64]);
        assert_eq!(b.y.len(), 4);
    }
}
