//! Synthetic dataset substrates (the repro has no access to CIFAR-100 /
//! YooChoose / DBPedia / Tiny-ImageNet; see DESIGN.md §2 for why each
//! generator preserves the paper-relevant structure).
//!
//! All generators are *deterministic functions of (seed, split, index)* —
//! samples are generated on the fly, so the feature owner and the label
//! owner independently materialize identical instance streams from the
//! shared experiment seed (the VFL alignment assumption), and no dataset
//! files are needed.

pub mod session;
pub mod tabular;
pub mod text;
pub mod vision;

use anyhow::{bail, Result};

use crate::runtime::HostTensor;
use crate::util::Rng;

pub use session::SynthSession;
pub use tabular::SynthTabular;
pub use text::SynthText;
pub use vision::SynthVision;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// One aligned batch: the feature owner consumes `x`, the label owner `y`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: HostTensor,
    pub y: Vec<i32>,
}

pub trait Dataset {
    fn name(&self) -> &str;
    fn len(&self, split: Split) -> usize;
    /// Materialize one sample's features into `x` (sample layout defined
    /// by the concrete generator) and return its label.
    fn sample(&self, split: Split, index: usize, augment: bool) -> (Vec<f32>, Vec<i32>, i32);
    /// Feature element count per sample and whether features are integer.
    fn feature_shape(&self) -> (Vec<usize>, bool);

    fn batch(&self, split: Split, indices: &[usize], augment: bool) -> Batch {
        let (shape, is_int) = self.feature_shape();
        let per: usize = shape.iter().product();
        let b = indices.len();
        let mut xf = Vec::with_capacity(if is_int { 0 } else { b * per });
        let mut xi = Vec::with_capacity(if is_int { b * per } else { 0 });
        let mut y = Vec::with_capacity(b);
        for &idx in indices {
            let (f, i, label) = self.sample(split, idx, augment);
            if is_int {
                debug_assert_eq!(i.len(), per);
                xi.extend_from_slice(&i);
            } else {
                debug_assert_eq!(f.len(), per);
                xf.extend_from_slice(&f);
            }
            y.push(label);
        }
        let mut full_shape = vec![b];
        full_shape.extend_from_slice(&shape);
        let x = if is_int {
            HostTensor::i32(xi, &full_shape)
        } else {
            HostTensor::f32(xf, &full_shape)
        };
        Batch { x, y }
    }
}

/// Shuffled fixed-size batch index iterator for one epoch (drops the
/// ragged tail so every batch matches the artifact's static batch size).
pub struct EpochIter {
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl EpochIter {
    pub fn new(n: usize, batch: usize, seed: u64, epoch: u32) -> Self {
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(seed ^ 0xE90C_15AB).fork(epoch as u64);
        rng.shuffle(&mut order);
        EpochIter { order, batch, pos: 0 }
    }

    /// Sequential (unshuffled) iteration — evaluation.
    pub fn sequential(n: usize, batch: usize) -> Self {
        EpochIter { order: (0..n).collect(), batch, pos: 0 }
    }

    pub fn num_batches(&self) -> usize {
        self.order.len() / self.batch
    }
}

impl Iterator for EpochIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let out = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        Some(out)
    }
}

/// Build the dataset matching a model name (geometry from the manifest).
/// A bad model name is an error, never a panic — serving processes route
/// request-supplied names through here.
pub fn for_model(
    model: &str,
    n_classes: usize,
    seed: u64,
    n_train: usize,
    n_test: usize,
) -> Result<Box<dyn Dataset>> {
    Ok(match model {
        "mlp" => Box::new(SynthTabular::new(n_classes, 64, seed, n_train, n_test)),
        "convnet" => Box::new(SynthVision::new(n_classes, 32, seed, n_train, n_test)),
        "convnet_l" => Box::new(SynthVision::new(n_classes, 32, seed, n_train, n_test)),
        "gru4rec" => Box::new(SynthSession::new(n_classes, 16, seed, n_train, n_test)),
        "textcnn" => Box::new(SynthText::new(n_classes, 5000, 32, seed, n_train, n_test)),
        other => bail!("no dataset for model '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_iter_covers_each_index_once() {
        let it = EpochIter::new(100, 10, 7, 0);
        let mut seen = vec![0usize; 100];
        let mut batches = 0;
        for idx in it {
            assert_eq!(idx.len(), 10);
            for i in idx {
                seen[i] += 1;
            }
            batches += 1;
        }
        assert_eq!(batches, 10);
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn epoch_iter_differs_by_epoch_same_by_seed() {
        let a: Vec<_> = EpochIter::new(64, 8, 7, 0).collect();
        let b: Vec<_> = EpochIter::new(64, 8, 7, 0).collect();
        let c: Vec<_> = EpochIter::new(64, 8, 7, 1).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn drops_ragged_tail() {
        let it = EpochIter::new(10, 4, 1, 0);
        assert_eq!(it.count(), 2);
    }

    #[test]
    fn for_model_rejects_unknown_model_without_panicking() {
        let err = for_model("resnet9000", 10, 1, 64, 32).err().expect("must error");
        assert!(err.to_string().contains("resnet9000"), "{err}");
        assert!(for_model("mlp", 100, 1, 64, 32).is_ok());
    }
}
