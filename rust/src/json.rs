//! Minimal JSON parser for `artifacts/manifest.json` (serde_json is not
//! available in the offline build). Supports the full JSON value grammar
//! minus exotic number forms; good enough for machine-generated manifests
//! and run-ledger output.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (used by the metrics ledger output).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push_str("{\n");
                let pad = " ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, indent);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), pos: self.pos }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.src.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.src[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let src = r#"{"models": {"mlp": {"batch": 32, "shapes": [[64, 256], [256]]}}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let v = Json::parse(&src).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}
