//! End-to-end split-step latency per compression method (one bench per
//! paper table's workload unit): full protocol step — bottom_fwd, encode,
//! frame, simulated link, decode, top_fwdbwd, gradient return, bottom_bwd —
//! measured on the mlp task.

use std::rc::Rc;

use splitfed::bench_util::Bench;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::Trainer;
use splitfed::data::{Dataset, Split};
use splitfed::runtime::{default_artifacts_dir, Engine};

fn main() {
    let engine = Rc::new(Engine::load(default_artifacts_dir()).expect("run `make artifacts`"));
    let mut b = Bench::new("e2e_step");
    b.min_time = 1.0;

    let methods = [
        "none",
        "randtopk:k=6,alpha=0.1",
        "topk:k=6",
        "sizered:k=6",
        "quant:bits=2",
        "l1:lambda=0.001",
    ];

    for spec in methods {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mlp".into();
        cfg.method = Method::parse(spec).unwrap();
        cfg.n_train = 256;
        cfg.n_test = 64;
        let mut trainer = Trainer::new(engine.clone(), cfg).unwrap();
        let indices: Vec<usize> = (0..trainer.fo.meta.batch).collect();
        let batch = trainer.dataset.batch(Split::Train, &indices, false);
        let mut step = 0u64;
        b.run(&format!("mlp train step [{spec}]"), || {
            trainer.fo.train_forward(step, &batch.x).unwrap();
            trainer.lo.train_step(step, &batch.y, 0.05).unwrap();
            trainer.fo.train_backward(step, 0.05).unwrap();
            step += 1;
        });
    }

    // eval step for the headline method
    {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mlp".into();
        cfg.method = Method::parse("randtopk:k=6,alpha=0.1").unwrap();
        cfg.n_train = 256;
        cfg.n_test = 64;
        let mut trainer = Trainer::new(engine.clone(), cfg).unwrap();
        let indices: Vec<usize> = (0..trainer.fo.meta.batch).collect();
        let batch = trainer.dataset.batch(Split::Test, &indices, false);
        let mut step = 0u64;
        b.run("mlp eval step [randtopk:k=6]", || {
            trainer.fo.eval_forward(step, &batch.x).unwrap();
            trainer.lo.eval_step(step, &batch.y).unwrap();
            trainer.fo.recv_eval_result().unwrap();
            step += 1;
        });
    }

    b.report();
}
