//! End-to-end split-step benches, in two tiers.
//!
//! **Engine-free (always runs, CI's tier):** the synthetic chaos workload
//! through the real codec/wire/mux stack, lockstep vs the windowed
//! pipelined executor at depth 1 / 2 / 4 — steps/sec per configuration,
//! written to `BENCH_pipeline.json`. The run FAILS (exit 1) if the
//! pipelined executor at depth 1 is materially slower than the straight
//! lockstep loop: depth 1 must be a free abstraction.
//!
//! **Engine-gated (artifacts present):** full protocol steps on the mlp
//! task per compression method — bottom_fwd, encode, frame, simulated
//! link, decode, top_fwdbwd, gradient return, bottom_bwd — plus the
//! lockstep `Trainer` vs two-thread `PipelinedTrainer` at depth 1 / 2,
//! and shared-vs-duplicated engine startup cost (the compile each
//! `serve_tcp` connection used to pay before engines were shared).

use std::sync::Arc;

use splitfed::bench_util::{Bench, CaseResult};
use splitfed::chaos::{run_session, run_session_lockstep, ChaosConfig};
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::{PipelinedTrainer, Trainer};
use splitfed::data::{Dataset, Split};
use splitfed::runtime::{default_artifacts_dir, Engine};
use splitfed::transport::FaultPlan;

/// Pipelined depth-1 may not be materially slower than lockstep. The
/// slack absorbs bench noise (shared CI runners); a real regression —
/// depth-1 paying for the window it never uses — lands far above it.
const DEPTH1_SLOWDOWN_TOLERANCE: f64 = 1.5;

fn synthetic_cfg() -> ChaosConfig {
    let mut cfg = ChaosConfig::quick(11, Method::Topk { k: 6 });
    // bench-sized: one call = one session, big enough to amortize setup
    cfg.rows = 16;
    cfg.cut_dim = 128;
    cfg.epochs = 1;
    cfg.steps_per_epoch = 32;
    cfg
}

fn bench_synthetic(b: &mut Bench) {
    let base = synthetic_cfg();
    let steps = (base.epochs * base.steps_per_epoch) as u64;
    {
        let cfg = base.clone();
        b.run_units("synthetic session lockstep reference (32 steps)", steps, move || {
            run_session_lockstep(&cfg, FaultPlan::none()).unwrap()
        });
    }
    for depth in [1usize, 2, 4] {
        let cfg = base.clone().with_depth(depth);
        b.run_units(
            &format!("synthetic session pipelined depth={depth} (32 steps)"),
            steps,
            move || run_session(&cfg, FaultPlan::none()).unwrap(),
        );
    }
}

fn mlp_cfg(spec: &str, depth: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.method = Method::parse(spec).unwrap();
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.epochs = 1;
    cfg.pipeline_depth = depth;
    cfg
}

fn bench_engine(b: &mut Bench, engine: &Arc<Engine>) {
    let methods = [
        "none",
        "randtopk:k=6,alpha=0.1",
        "topk:k=6",
        "sizered:k=6",
        "quant:bits=2",
        "l1:lambda=0.001",
    ];

    for spec in methods {
        let mut trainer = Trainer::new(engine.clone(), mlp_cfg(spec, 1)).unwrap();
        let indices: Vec<usize> = (0..trainer.fo.meta.batch).collect();
        let batch = trainer.dataset.batch(Split::Train, &indices, false);
        let mut step = 0u64;
        b.run(&format!("mlp train step [{spec}]"), || {
            trainer.fo.train_forward(step, &batch.x).unwrap();
            trainer.lo.train_step(step, &batch.y, 0.05).unwrap();
            trainer.fo.train_backward(step, 0.05).unwrap();
            step += 1;
        });
    }

    // eval step for the headline method
    {
        let mut trainer =
            Trainer::new(engine.clone(), mlp_cfg("randtopk:k=6,alpha=0.1", 1)).unwrap();
        let indices: Vec<usize> = (0..trainer.fo.meta.batch).collect();
        let batch = trainer.dataset.batch(Split::Test, &indices, false);
        let mut step = 0u64;
        b.run("mlp eval step [randtopk:k=6]", || {
            trainer.fo.eval_forward(step, &batch.x).unwrap();
            trainer.lo.eval_step(step, &batch.y).unwrap();
            trainer.fo.recv_eval_result().unwrap();
            step += 1;
        });
    }

    // lockstep Trainer vs the two-thread PipelinedTrainer: one call = one
    // epoch over 256 samples = 8 steps; units make steps/sec comparable
    let steps = (256 / 32) as u64;
    {
        let engine = engine.clone();
        b.run_units("mlp epoch lockstep Trainer [randtopk:k=6] (8 steps)", steps, move || {
            let mut t = Trainer::new(engine.clone(), mlp_cfg("randtopk:k=6,alpha=0.1", 1))
                .unwrap();
            t.run().unwrap()
        });
    }
    for depth in [1usize, 2] {
        let engine = engine.clone();
        b.run_units(
            &format!("mlp epoch pipelined depth={depth} [randtopk:k=6] (8 steps)"),
            steps,
            move || {
                let mut t = PipelinedTrainer::new(
                    engine.clone(),
                    mlp_cfg("randtopk:k=6,alpha=0.1", depth),
                )
                .unwrap();
                t.run().unwrap()
            },
        );
    }

    // shared vs duplicated engine: what each serve_tcp connection used to
    // pay (its own Engine::load + compile) vs a warm shared-cache fetch.
    // Hand-timed over a few reps — a compile per bench iteration would
    // drown the adaptive harness.
    let key = "mlp/dense/bottom_fwd";
    let dir = default_artifacts_dir();
    let reps = 3u32;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let fresh = Engine::load(&dir).unwrap();
        fresh.executable(key).unwrap();
    }
    let dup_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    engine.executable(key).unwrap(); // warm the shared cache
    let t1 = std::time::Instant::now();
    let hot_reps = 10_000u32;
    for _ in 0..hot_reps {
        engine.executable(key).unwrap();
    }
    let shared_ns = t1.elapsed().as_nanos() as f64 / hot_reps as f64;
    for (name, mean_ns, iters) in [
        ("engine per connection (load + compile, old serve_tcp)", dup_ns, reps as u64),
        ("engine shared across connections (warm cache fetch)", shared_ns, hot_reps as u64),
    ] {
        b.results.push(CaseResult {
            name: name.into(),
            mean_ns,
            std_ns: 0.0,
            min_ns: mean_ns,
            p99_ns: mean_ns,
            iters,
            bytes: None,
            units: None,
        });
    }
}

fn main() {
    let mut b = Bench::new("pipeline");
    b.min_time = 1.0;

    bench_synthetic(&mut b);

    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let engine = Arc::new(Engine::load(&dir).expect("run `make artifacts`"));
        bench_engine(&mut b, &engine);
    } else {
        eprintln!("artifacts missing; engine-gated cases skipped (synthetic tier still ran)");
    }

    b.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json");
    match b.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    // regression gate: depth-1 pipelining must be (near-)free. Checked on
    // the synthetic tier (always present) and the mlp tier when it ran.
    let mut failed = false;
    for (lockstep, pipelined) in [
        ("synthetic session lockstep", "synthetic session pipelined depth=1"),
        ("mlp epoch lockstep", "mlp epoch pipelined depth=1"),
    ] {
        let (Some(base), Some(d1)) = (b.mean_of(lockstep), b.mean_of(pipelined)) else {
            continue;
        };
        if d1 > base * DEPTH1_SLOWDOWN_TOLERANCE {
            eprintln!(
                "FAIL: '{pipelined}' ({:.2} ms) is more than {DEPTH1_SLOWDOWN_TOLERANCE}x \
                 slower than '{lockstep}' ({:.2} ms)",
                d1 / 1e6,
                base / 1e6
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
}
