//! Fleet-scale load bench for the batching plane (ROADMAP "fleet-scale
//! serving" item): ~1k simulated feature-owner clients with churn —
//! connect, train, drop mid-bucket, resume, renegotiate — driven
//! cooperatively on one thread over real codec payloads, the real wire
//! format, and the real mux, into the real `Coalescer`.
//!
//! Engine-free: execution is a synthetic cost model — a fixed
//! per-dispatch overhead (compile-cache lookup, marshal, launch,
//! readback) plus per-row math, burned in real wall-clock work. The
//! batching plane's whole bet is amortizing the fixed term across
//! bucket-mates; everything else (encode, frame, mux route, decode,
//! assemble+pad, scatter, reply) is the production code path.
//!
//! Phases:
//!
//! 1. per-client dispatch baseline at 1k clients (`max_coalesce = 1`) —
//!    aggregate steps/sec;
//! 2. coalesced at 1k clients (`max_coalesce = 32`) — aggregate
//!    steps/sec, for the speedup gate;
//! 3. burst latency probes (32 concurrent requests) through both
//!    configurations — per-client p99 step latency, against an
//!    uncoalesced 32-client reference roster;
//! 4. churn at 256 clients: drop-after-send (a parked request's stream
//!    dies mid-bucket), drop-then-resume on a fresh stream, and
//!    renegotiate to a different variant (its own coalescing group).
//!    Clients that connect and drop before any reply have EMPTY latency
//!    samples — their per-client quantile is `Quantile::Empty`, counted,
//!    not a panic.
//!
//! Emits `BENCH_fleet.json` at the repo root. Exits nonzero if coalesced
//! steps/sec at 1k clients is under 1.5x the per-client baseline from
//! the SAME run, or if coalesced p99 step latency at 1k clients exceeds
//! 2x the uncoalesced p99 at 32 clients.

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use splitfed::bench_util::{fmt_ns, p99_ns, quantile_ns};
use splitfed::compress::{
    codec_for_layout, Batch, Codec, CodecSpec, IndexLayout, Pass, SparseBatch,
};
use splitfed::config::Method;
use splitfed::coordinator::{
    assemble, bucket_for, pump_conn, CoalescePolicy, Coalescer, PendingRequest,
};
use splitfed::json::Json;
use splitfed::transport::sim::{LinkModel, SimLink, SimNet};
use splitfed::transport::{Mux, MuxConfig, MuxEvent, MuxStream, Transport, TransportError};
use splitfed::util::Rng;
use splitfed::wire::{Frame, Message, OpenSpec};

const FLEET: usize = 1_000;
const CONNS: usize = 8;
const STEPS: u64 = 4;
const DIM: usize = 128;
const K: usize = 6;
const ROWS: usize = 32;
const MAX_COALESCE: usize = 32;
const BATCH_DELAY_US: u64 = 200;
/// Gate: coalesced steps/sec at 1k clients vs per-client dispatch.
const SPEEDUP_LIMIT: f64 = 1.5;
/// Gate: coalesced p99 at 1k clients vs uncoalesced p99 at 32 clients.
const P99_RATIO_LIMIT: f64 = 2.0;
const PROBE_BURSTS: usize = 50;
const PROBE_BURST_SIZE: usize = 32;

/// Synthetic execution cost, in units of one dependent sqrt (~ns each):
/// the fixed term is what coalescing amortizes; the per-row term is what
/// both paths pay alike (padding rows included — padding is not free).
const DISPATCH_OVERHEAD_ITERS: u64 = 20_000;
const PER_ROW_ITERS: u64 = 40;

fn burn(iters: u64) {
    let mut acc = 0.0f64;
    for i in 0..iters {
        acc += std::hint::black_box((i as f64) * 1.000000119).sqrt();
    }
    std::hint::black_box(acc);
}

fn is_would_block(e: &anyhow::Error) -> bool {
    TransportError::of(e) == Some(TransportError::WouldBlock)
}

/// One simulated feature owner: a stream, its codec, a fixed activation
/// batch it re-sends each step, and its latency samples.
struct Client {
    conn: usize,
    stream: MuxStream<SimLink>,
    codec: Box<dyn Codec>,
    batch: Batch,
    spec: CodecSpec,
    step: u64,
    done: u64,
    outstanding: Option<(u64, Instant)>,
    samples: Vec<f64>,
    alive: bool,
}

impl Client {
    fn send_step(&mut self) -> anyhow::Result<()> {
        let payload = self.codec.encode(&self.batch, Pass::Forward)?;
        let frame = Frame::new(0, Message::Activations { step: self.step, payload });
        self.outstanding = Some((self.step, Instant::now()));
        self.stream.send(&frame)
    }

    /// Drain any replies; record a latency sample per completed step.
    fn poll_replies(&mut self) -> anyhow::Result<bool> {
        let mut progressed = false;
        loop {
            match self.stream.recv() {
                Ok(f) => {
                    let Message::EvalResult { step, .. } = f.message else {
                        anyhow::bail!("unexpected reply {:?}", f.message.msg_type());
                    };
                    let Some((sent_step, t0)) = self.outstanding.take() else {
                        anyhow::bail!("reply with nothing outstanding");
                    };
                    anyhow::ensure!(step == sent_step, "reply step {step} != {sent_step}");
                    self.samples.push(t0.elapsed().as_nanos() as f64);
                    self.step += 1;
                    self.done += 1;
                    progressed = true;
                }
                Err(e) if is_would_block(&e) => return Ok(progressed),
                Err(e) => return Err(e),
            }
        }
    }
}

/// Server side of one physical connection: accepted streams, their
/// negotiated codecs, and this connection's coalescer.
struct ServerConn {
    mux: Mux<SimLink>,
    streams: HashMap<u32, MuxStream<SimLink>>,
    codecs: HashMap<u32, (Box<dyn Codec>, String)>,
    coalescer: Coalescer,
    served: u64,
    dispatches: u64,
}

/// Burn the cost model for one group and reply per request. The group is
/// already same-variant; padding rows (bucket - n clients) burn too.
fn dispatch(
    group: Vec<PendingRequest>,
    max: usize,
    streams: &mut HashMap<u32, MuxStream<SimLink>>,
    served: &mut u64,
) -> anyhow::Result<()> {
    if group.is_empty() {
        return Ok(());
    }
    let bucket = bucket_for(group.len(), max);
    if bucket > 1 {
        let (stacked, _y) = assemble(&group, bucket)?;
        burn(DISPATCH_OVERHEAD_ITERS + PER_ROW_ITERS * stacked.rows() as u64);
    } else {
        burn(DISPATCH_OVERHEAD_ITERS + PER_ROW_ITERS * group[0].batch.rows() as u64);
    }
    for req in group {
        *served += 1;
        if let Some(s) = streams.get_mut(&req.stream_id) {
            // a dead stream drops its reply without failing the bucket
            let _ = s.send(&Frame::new(
                0,
                Message::EvalResult {
                    step: req.step,
                    loss_sum: req.step as f32,
                    metric_count: 1.0,
                },
            ));
        }
    }
    Ok(())
}

impl ServerConn {
    /// Pump mux events into the coalescer, then dispatch whatever the
    /// policy says is ready (full buckets now, ragged groups past the
    /// deadline).
    fn pump_and_flush(&mut self) -> anyhow::Result<()> {
        let ServerConn { mux, streams, codecs, coalescer, served, dispatches } = self;
        pump_conn(mux, 4096, &mut |m, ev| {
            match ev {
                MuxEvent::Opened(id) => {
                    let OpenSpec::Spec(s) = m.stream_spec(id).unwrap_or_default() else {
                        anyhow::bail!("fleet clients always open with a spec");
                    };
                    let codec = codec_for_layout(s.method, s.cut_dim, s.index_layout)?;
                    codecs.insert(id, (codec, s.method.variant()));
                    streams.insert(id, m.accept_stream(id)?);
                }
                MuxEvent::Data(id) => {
                    let s = streams
                        .get_mut(&id)
                        .ok_or_else(|| anyhow::anyhow!("data for unknown stream {id}"))?;
                    let f = s.recv()?;
                    let Message::Activations { step, payload } = f.message else {
                        anyhow::bail!("unexpected request {:?}", f.message.msg_type());
                    };
                    let (codec, variant) = &codecs[&id];
                    let batch = codec.decode(&payload, Pass::Forward)?;
                    let rows = batch.rows();
                    coalescer.push(
                        variant,
                        PendingRequest {
                            stream_id: id,
                            step,
                            batch,
                            y: vec![0; rows],
                            enqueued_at: Instant::now(),
                        },
                    );
                }
                MuxEvent::Closed(id) => {
                    // mid-bucket drop: the departing stream's parked
                    // requests dispatch alone (replies go nowhere); its
                    // bucket-mates stay parked, untouched
                    let max = coalescer.policy().max_coalesce;
                    for (_, group) in coalescer.take_stream(id) {
                        *dispatches += 1;
                        dispatch(group, max, streams, served)?;
                    }
                    streams.remove(&id);
                    codecs.remove(&id);
                }
                _ => {}
            }
            Ok(false)
        })?;
        let max = self.coalescer.policy().max_coalesce;
        for (_, group) in self.coalescer.take_ready(Instant::now(), false) {
            self.dispatches += 1;
            dispatch(group, max, &mut self.streams, &mut self.served)?;
        }
        Ok(())
    }
}

struct Fleet {
    clients: Vec<Client>,
    client_muxes: Vec<Mux<SimLink>>,
    servers: Vec<ServerConn>,
}

fn random_batch(rng: &mut Rng) -> Batch {
    let mut values = Vec::with_capacity(ROWS * K);
    let mut indices = Vec::with_capacity(ROWS * K);
    for _ in 0..ROWS {
        let mut all: Vec<i32> = (0..DIM as i32).collect();
        rng.shuffle(&mut all);
        let mut sel = all[..K].to_vec();
        sel.sort_unstable();
        for &i in &sel {
            indices.push(i);
            values.push(rng.normal());
        }
    }
    Batch::Sparse(SparseBatch { rows: ROWS, dim: DIM, k: K, values, indices })
}

/// Spec for client `i`: everyone runs top-k at the same k (one coalescing
/// group), a quarter of the fleet negotiating LEB128-delta indices — a
/// different wire layout decodes into the SAME variant group.
fn client_spec(i: usize) -> CodecSpec {
    let layout = if i % 4 == 0 { IndexLayout::Leb128Delta } else { IndexLayout::Bitpack };
    CodecSpec::new(Method::Topk { k: K }, DIM).with_index_layout(layout)
}

fn build_fleet(n: usize, conns: usize, policy: CoalescePolicy) -> anyhow::Result<Fleet> {
    let mut client_muxes = Vec::with_capacity(conns);
    let mut servers = Vec::with_capacity(conns);
    for _ in 0..conns {
        let net = SimNet::new(LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 });
        let (a, b) = net.pair();
        client_muxes.push(Mux::with_config(a, MuxConfig::initiator())?);
        servers.push(ServerConn {
            mux: Mux::with_config(b, MuxConfig::acceptor())?,
            streams: HashMap::new(),
            codecs: HashMap::new(),
            coalescer: Coalescer::new(policy),
            served: 0,
            dispatches: 0,
        });
    }
    let mut clients = Vec::with_capacity(n);
    let mut rng = Rng::new(4242);
    for i in 0..n {
        let conn = i % conns;
        let spec = client_spec(i);
        let stream = client_muxes[conn].open_stream_with(spec)?;
        clients.push(Client {
            conn,
            stream,
            codec: spec.codec()?,
            batch: random_batch(&mut rng),
            spec,
            step: 0,
            done: 0,
            outstanding: None,
            samples: Vec::new(),
            alive: true,
        });
    }
    Ok(Fleet { clients, client_muxes, servers })
}

/// Pop client-side housekeeping events so queues stay flat.
fn drain_client_events(mux: &Mux<SimLink>) -> anyhow::Result<()> {
    loop {
        match mux.next_event() {
            Ok(_) => {}
            Err(e) if is_would_block(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
    }
}

/// Drive every client to `steps` completed steps (one request in flight
/// per client); returns aggregate steps/sec.
fn run_throughput(fleet: &mut Fleet, steps: u64) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    let target: u64 = fleet.clients.iter().filter(|c| c.alive).count() as u64 * steps;
    let mut completed = 0u64;
    let mut stalls = 0u64;
    while completed < target {
        let mut progressed = false;
        for c in fleet.clients.iter_mut() {
            if c.alive && c.outstanding.is_none() && c.done < steps {
                c.send_step()?;
                progressed = true;
            }
        }
        for sc in fleet.servers.iter_mut() {
            sc.pump_and_flush()?;
        }
        for mux in &fleet.client_muxes {
            drain_client_events(mux)?;
        }
        for c in fleet.clients.iter_mut() {
            if c.alive && c.poll_replies()? {
                progressed = true;
            }
        }
        completed =
            fleet.clients.iter().filter(|c| c.alive).map(|c| c.done.min(steps)).sum();
        if progressed {
            stalls = 0;
        } else {
            stalls += 1;
            anyhow::ensure!(
                t0.elapsed().as_secs() < 60,
                "fleet stalled at {completed}/{target} steps after {stalls} idle sweeps"
            );
            // a ragged tail is parked on the batch deadline; let it age
            std::thread::yield_now();
        }
    }
    Ok(target as f64 / t0.elapsed().as_secs_f64())
}

/// Burst latency probes: `PROBE_BURSTS` rounds of `PROBE_BURST_SIZE`
/// concurrent single requests through the live roster; per-request
/// send-to-reply latency lands in each prober's samples.
fn run_probes(fleet: &mut Fleet) -> anyhow::Result<Vec<f64>> {
    let n = fleet.clients.len();
    let mut all = Vec::with_capacity(PROBE_BURSTS * PROBE_BURST_SIZE);
    for round in 0..PROBE_BURSTS {
        let mut probers = Vec::with_capacity(PROBE_BURST_SIZE);
        for j in 0..PROBE_BURST_SIZE {
            let idx = (round * 7919 + j * 131) % n;
            if fleet.clients[idx].alive && fleet.clients[idx].outstanding.is_none() {
                probers.push(idx);
            }
        }
        probers.sort_unstable();
        probers.dedup();
        for &idx in &probers {
            fleet.clients[idx].send_step()?;
        }
        let burst_t0 = Instant::now();
        while probers.iter().any(|&i| fleet.clients[i].outstanding.is_some()) {
            for sc in fleet.servers.iter_mut() {
                sc.pump_and_flush()?;
            }
            for mux in &fleet.client_muxes {
                drain_client_events(mux)?;
            }
            for &idx in &probers {
                fleet.clients[idx].poll_replies()?;
            }
            anyhow::ensure!(
                burst_t0.elapsed().as_secs() < 30,
                "probe burst {round} never completed"
            );
        }
        for &idx in &probers {
            all.push(*fleet.clients[idx].samples.last().expect("probe recorded a sample"));
        }
    }
    Ok(all)
}

struct ChurnStats {
    connected: usize,
    dropped: usize,
    resumed: usize,
    renegotiated: usize,
    empty_latency_clients: usize,
    steps_completed: u64,
}

/// Churn: a smaller coalesced fleet where scripted clients drop with a
/// request still parked in a bucket (their bucket-mates must complete),
/// some of those resume on a fresh stream, and some renegotiate to a
/// different k (a different variant = its own coalescing group).
fn run_churn() -> anyhow::Result<ChurnStats> {
    const CH_FLEET: usize = 256;
    const CH_CONNS: usize = 4;
    let policy = CoalescePolicy::new(16, BATCH_DELAY_US);
    let mut fleet = build_fleet(CH_FLEET, CH_CONNS, policy)?;
    let mut rng = Rng::new(99);

    // phase A: everyone completes one step
    run_throughput(&mut fleet, 1)?;

    // phase B: scripted churn
    let mut dropped = 0;
    let mut resumed = 0;
    let mut renegotiated = 0;
    for i in 0..CH_FLEET {
        match i % 8 {
            // drop mid-bucket: send a request, close before the reply —
            // the server flushes the parked request at Closed and the
            // reply lands nowhere; bucket-mates must still finish
            3 => {
                let c = &mut fleet.clients[i];
                c.send_step()?;
                c.stream.close()?;
                c.alive = false;
                dropped += 1;
            }
            // drop then resume: close cleanly, reopen a fresh stream with
            // the same spec, keep stepping
            5 => {
                let (conn, spec) = {
                    let c = &mut fleet.clients[i];
                    c.stream.close()?;
                    (c.conn, c.spec)
                };
                let stream = fleet.client_muxes[conn].open_stream_with(spec)?;
                let c = &mut fleet.clients[i];
                c.stream = stream;
                c.step = 0; // a fresh stream is a fresh session
                c.done = 0;
                dropped += 1;
                resumed += 1;
            }
            // renegotiate: a fresh stream under a different variant — its
            // requests coalesce in their own group next to everyone else's
            7 => {
                let conn = fleet.clients[i].conn;
                fleet.clients[i].stream.close()?;
                let spec = CodecSpec::new(Method::Topk { k: 13 }, DIM);
                let stream = fleet.client_muxes[conn].open_stream_with(spec)?;
                let c = &mut fleet.clients[i];
                c.stream = stream;
                c.spec = spec;
                c.codec = spec.codec()?;
                c.step = 0;
                c.done = 0;
                // k=13 geometry needs a matching batch
                let mut values = Vec::with_capacity(ROWS * 13);
                let mut indices = Vec::with_capacity(ROWS * 13);
                for _ in 0..ROWS {
                    let mut all: Vec<i32> = (0..DIM as i32).collect();
                    rng.shuffle(&mut all);
                    let mut sel = all[..13].to_vec();
                    sel.sort_unstable();
                    for &v in &sel {
                        indices.push(v);
                        values.push(rng.normal());
                    }
                }
                c.batch =
                    Batch::Sparse(SparseBatch { rows: ROWS, dim: DIM, k: 13, values, indices });
                renegotiated += 1;
            }
            _ => {}
        }
    }

    // flash connections: connect, send once, drop before any reply — a
    // client whose entire lifetime is one parked request. Zero latency
    // samples, so its per-client quantile is `Quantile::Empty`.
    let flash = 8;
    for f in 0..flash {
        let conn = f % CH_CONNS;
        let spec = client_spec(f);
        let stream = fleet.client_muxes[conn].open_stream_with(spec)?;
        let mut c = Client {
            conn,
            stream,
            codec: spec.codec()?,
            batch: random_batch(&mut rng),
            spec,
            step: 0,
            done: 0,
            outstanding: None,
            samples: Vec::new(),
            alive: true,
        };
        c.send_step()?;
        c.stream.close()?;
        c.alive = false;
        dropped += 1;
        fleet.clients.push(c);
    }

    // phase C: the survivors (including resumed + renegotiated) finish
    run_throughput(&mut fleet, 2)?;

    // per-client quantiles: the connect-then-drop clients have EMPTY
    // sample sets — the typed Quantile handles them without panicking
    let empty_latency_clients = fleet
        .clients
        .iter()
        .filter(|c| p99_ns(&c.samples).is_empty())
        .count();
    let steps_completed = fleet.servers.iter().map(|s| s.served).sum();
    Ok(ChurnStats {
        connected: CH_FLEET + resumed + renegotiated + flash,
        dropped,
        resumed,
        renegotiated,
        empty_latency_clients,
        steps_completed,
    })
}

struct PhaseStats {
    steps_per_sec: f64,
    p50_ns: f64,
    p99_ns: f64,
    dispatches: u64,
    served: u64,
}

fn run_config(n: usize, policy: CoalescePolicy) -> anyhow::Result<PhaseStats> {
    let mut fleet = build_fleet(n, CONNS.min(n), policy)?;
    let steps_per_sec = run_throughput(&mut fleet, STEPS)?;
    let samples = run_probes(&mut fleet)?;
    Ok(PhaseStats {
        steps_per_sec,
        p50_ns: quantile_ns(&samples, 0.5).unwrap_or(f64::NAN),
        p99_ns: quantile_ns(&samples, 0.99).unwrap_or(f64::NAN),
        dispatches: fleet.servers.iter().map(|s| s.dispatches).sum(),
        served: fleet.servers.iter().map(|s| s.served).sum(),
    })
}

fn phase_json(label: &str, clients: usize, s: &PhaseStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("config".to_string(), Json::Str(label.to_string()));
    m.insert("clients".to_string(), Json::Num(clients as f64));
    m.insert("steps_per_sec".to_string(), Json::Num(s.steps_per_sec));
    m.insert("p50_step_ns".to_string(), Json::Num(s.p50_ns));
    m.insert("p99_step_ns".to_string(), Json::Num(s.p99_ns));
    m.insert("dispatches".to_string(), Json::Num(s.dispatches as f64));
    m.insert("requests_served".to_string(), Json::Num(s.served as f64));
    Json::Obj(m)
}

fn main() {
    println!("== bench group: fleet ==");
    let per_client = CoalescePolicy::new(1, 0);
    let coalesced = CoalescePolicy::new(MAX_COALESCE, BATCH_DELAY_US);

    let base_1k = run_config(FLEET, per_client).unwrap_or_else(|e| panic!("baseline 1k: {e:#}"));
    println!(
        "per-client @{FLEET}: {:>9.0} steps/s  p50 {:>10}  p99 {:>10}  ({} dispatches)",
        base_1k.steps_per_sec,
        fmt_ns(base_1k.p50_ns),
        fmt_ns(base_1k.p99_ns),
        base_1k.dispatches
    );
    let coal_1k = run_config(FLEET, coalesced).unwrap_or_else(|e| panic!("coalesced 1k: {e:#}"));
    println!(
        "coalesced  @{FLEET}: {:>9.0} steps/s  p50 {:>10}  p99 {:>10}  ({} dispatches)",
        coal_1k.steps_per_sec,
        fmt_ns(coal_1k.p50_ns),
        fmt_ns(coal_1k.p99_ns),
        coal_1k.dispatches
    );
    let base_32 = run_config(32, per_client).unwrap_or_else(|e| panic!("baseline 32: {e:#}"));
    println!(
        "per-client @32  : {:>9.0} steps/s  p50 {:>10}  p99 {:>10}",
        base_32.steps_per_sec,
        fmt_ns(base_32.p50_ns),
        fmt_ns(base_32.p99_ns)
    );

    let churn = run_churn().unwrap_or_else(|e| panic!("churn: {e:#}"));
    println!(
        "churn @256: {} connected, {} dropped ({} resumed, {} renegotiated), \
         {} served; {} clients with empty latency samples",
        churn.connected,
        churn.dropped,
        churn.resumed,
        churn.renegotiated,
        churn.steps_completed,
        churn.empty_latency_clients
    );

    let speedup = coal_1k.steps_per_sec / base_1k.steps_per_sec;
    let p99_ratio = coal_1k.p99_ns / base_32.p99_ns;
    let speedup_ok = speedup >= SPEEDUP_LIMIT;
    let p99_ok = p99_ratio <= P99_RATIO_LIMIT;
    println!(
        "\ncoalesced speedup {speedup:.2}x (gate >= {SPEEDUP_LIMIT}); \
         p99 @1k vs uncoalesced @32: {p99_ratio:.2}x (gate <= {P99_RATIO_LIMIT})"
    );

    let mut top = BTreeMap::new();
    top.insert("group".to_string(), Json::Str("fleet".to_string()));
    let mut model = BTreeMap::new();
    model.insert("clients".to_string(), Json::Num(FLEET as f64));
    model.insert("connections".to_string(), Json::Num(CONNS as f64));
    model.insert("steps_per_client".to_string(), Json::Num(STEPS as f64));
    model.insert("rows_per_request".to_string(), Json::Num(ROWS as f64));
    model.insert("max_coalesce".to_string(), Json::Num(MAX_COALESCE as f64));
    model.insert("max_batch_delay_us".to_string(), Json::Num(BATCH_DELAY_US as f64));
    model.insert(
        "dispatch_overhead_iters".to_string(),
        Json::Num(DISPATCH_OVERHEAD_ITERS as f64),
    );
    model.insert("per_row_iters".to_string(), Json::Num(PER_ROW_ITERS as f64));
    top.insert("cost_model".to_string(), Json::Obj(model));
    top.insert(
        "phases".to_string(),
        Json::Arr(vec![
            phase_json("per_client", FLEET, &base_1k),
            phase_json("coalesced", FLEET, &coal_1k),
            phase_json("per_client", 32, &base_32),
        ]),
    );
    let mut ch = BTreeMap::new();
    ch.insert("clients".to_string(), Json::Num(256.0));
    ch.insert("connected".to_string(), Json::Num(churn.connected as f64));
    ch.insert("dropped".to_string(), Json::Num(churn.dropped as f64));
    ch.insert("resumed".to_string(), Json::Num(churn.resumed as f64));
    ch.insert("renegotiated".to_string(), Json::Num(churn.renegotiated as f64));
    ch.insert(
        "empty_latency_clients".to_string(),
        Json::Num(churn.empty_latency_clients as f64),
    );
    ch.insert("requests_served".to_string(), Json::Num(churn.steps_completed as f64));
    top.insert("churn".to_string(), Json::Obj(ch));
    let mut gates = BTreeMap::new();
    gates.insert("speedup_limit".to_string(), Json::Num(SPEEDUP_LIMIT));
    gates.insert("coalesced_speedup".to_string(), Json::Num(speedup));
    gates.insert("speedup_ok".to_string(), Json::Bool(speedup_ok));
    gates.insert("p99_ratio_limit".to_string(), Json::Num(P99_RATIO_LIMIT));
    gates.insert("p99_coalesced_1k_ns".to_string(), Json::Num(coal_1k.p99_ns));
    gates.insert("p99_per_client_32_ns".to_string(), Json::Num(base_32.p99_ns));
    gates.insert("p99_ratio".to_string(), Json::Num(p99_ratio));
    gates.insert("p99_ok".to_string(), Json::Bool(p99_ok));
    gates.insert("pass".to_string(), Json::Bool(speedup_ok && p99_ok));
    top.insert("gates".to_string(), Json::Obj(gates));

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fleet.json");
    match std::fs::write(out, Json::Obj(top).to_string_pretty()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    if !speedup_ok {
        eprintln!(
            "GATE FAIL: coalesced dispatch at {FLEET} clients is only {speedup:.2}x the \
             per-client baseline (limit {SPEEDUP_LIMIT}x)"
        );
    }
    if !p99_ok {
        eprintln!(
            "GATE FAIL: coalesced p99 at {FLEET} clients is {p99_ratio:.2}x the uncoalesced \
             32-client p99 (limit {P99_RATIO_LIMIT}x)"
        );
    }
    if !(speedup_ok && p99_ok) {
        std::process::exit(1);
    }
}
