//! Transport + wire benches: framing overhead and link throughput for the
//! message sizes the paper's workloads actually generate, plus the mux
//! layer's per-frame overhead vs. the single-stream path. Emits
//! `BENCH_transport.json` at the repo root for the perf trajectory.

use splitfed::bench_util::Bench;
use splitfed::compress::Payload;
use splitfed::transport::sim::{LinkModel, SimNet};
use splitfed::transport::{Mux, MuxEvent, TcpTransport, Transport};
use splitfed::wire::{Frame, Message};

fn frame_of(bytes: usize) -> Frame {
    Frame::new(
        1,
        Message::Activations {
            step: 1,
            payload: Payload::dense(32, bytes / 4 / 32, vec![0xAB; bytes]),
        },
    )
}

fn fast_net() -> SimNet {
    SimNet::new(LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 })
}

fn main() {
    let mut b = Bench::new("transport");
    b.min_time = 0.5;

    // wire encode/decode
    for size in [768usize, 16 * 1024, 160 * 1024] {
        let f = frame_of(size);
        let encoded = f.encode();
        b.run_bytes(&format!("frame encode {size}B"), size as u64, || f.encode());
        b.run_bytes(&format!("frame decode {size}B"), size as u64, || {
            Frame::decode(&encoded).unwrap()
        });
    }

    // sim link round trip (no network model cost, just queueing + codec)
    {
        let net = fast_net();
        let (mut a, mut bb) = net.pair();
        let f = frame_of(16 * 1024);
        b.run_bytes("simlink send+recv 16KiB", 16 * 1024, || {
            a.send(&f).unwrap();
            bb.recv().unwrap()
        });
    }

    // mux over the same sim link: measures demux + restamp + accounting
    // overhead relative to the single-stream case above
    {
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::initiator(a);
        let sm = Mux::acceptor(bb);
        let mut cs = cm.open_stream().unwrap();
        assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
        let mut ss = sm.accept_stream(cs.id()).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("mux simlink send+recv 16KiB (1 stream)", 16 * 1024, || {
            cs.send(&f).unwrap();
            ss.recv().unwrap()
        });
    }

    // mux with 8 interleaved streams: per-frame routing under contention
    {
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::initiator(a);
        let sm = Mux::acceptor(bb);
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..8 {
            let cs = cm.open_stream().unwrap();
            assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
            receivers.push(sm.accept_stream(cs.id()).unwrap());
            senders.push(cs);
        }
        let f = frame_of(16 * 1024);
        b.run_bytes("mux simlink 8-stream interleave 8x16KiB", 8 * 16 * 1024, || {
            for s in senders.iter_mut() {
                s.send(&f).unwrap();
            }
            for r in receivers.iter_mut() {
                r.recv().unwrap();
            }
        });
    }

    // TCP loopback round trip, single stream
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(stream);
            loop {
                match t.recv() {
                    Ok(f) => {
                        if matches!(f.message, Message::Control(_)) {
                            break;
                        }
                        t.send(&f).unwrap();
                    }
                    Err(_) => break,
                }
            }
        });
        let mut client = TcpTransport::connect(addr).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            client.send(&f).unwrap();
            client.recv().unwrap()
        });
        client
            .send(&Frame::new(0, Message::Control(splitfed::wire::Control::Shutdown)))
            .unwrap();
        echo.join().unwrap();
    }

    // mux over TCP loopback: the deployment path of serve_inference
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let sm = Mux::acceptor(TcpTransport::from_stream(stream));
            let MuxEvent::Opened(id) = sm.next_event().unwrap() else {
                panic!("expected stream open");
            };
            let mut t = sm.accept_stream(id).unwrap();
            loop {
                match t.recv() {
                    Ok(f) => t.send(&f).unwrap(),
                    Err(_) => break, // CloseStream or hangup
                }
            }
        });
        let cm = Mux::initiator(TcpTransport::connect(addr).unwrap());
        let mut cs = cm.open_stream().unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("mux tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            cs.send(&f).unwrap();
            cs.recv().unwrap()
        });
        cs.close().unwrap();
        echo.join().unwrap();
    }

    b.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_transport.json");
    match b.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}
