//! Transport + wire benches: framing overhead and link throughput for the
//! message sizes the paper's workloads actually generate.

use splitfed::bench_util::Bench;
use splitfed::compress::Payload;
use splitfed::transport::sim::{LinkModel, SimNet};
use splitfed::transport::{TcpTransport, Transport};
use splitfed::wire::{Frame, Message};

fn frame_of(bytes: usize) -> Frame {
    Frame {
        seq: 1,
        message: Message::Activations {
            step: 1,
            payload: Payload::Dense { rows: 32, dim: bytes / 4 / 32, bytes: vec![0xAB; bytes] },
        },
    }
}

fn main() {
    let mut b = Bench::new("transport");
    b.min_time = 0.5;

    // wire encode/decode
    for size in [768usize, 16 * 1024, 160 * 1024] {
        let f = frame_of(size);
        let encoded = f.encode();
        b.run_bytes(&format!("frame encode {size}B"), size as u64, || f.encode());
        b.run_bytes(&format!("frame decode {size}B"), size as u64, || {
            Frame::decode(&encoded).unwrap()
        });
    }

    // sim link round trip (no network model cost, just queueing + codec)
    {
        let net = SimNet::new(LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 });
        let (mut a, mut bb) = net.pair();
        let f = frame_of(16 * 1024);
        b.run_bytes("simlink send+recv 16KiB", 16 * 1024, || {
            a.send(&f).unwrap();
            bb.recv().unwrap()
        });
    }

    // TCP loopback round trip
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(stream);
            loop {
                match t.recv() {
                    Ok(f) => {
                        if matches!(f.message, Message::Control(_)) {
                            break;
                        }
                        t.send(&f).unwrap();
                    }
                    Err(_) => break,
                }
            }
        });
        let mut client = TcpTransport::connect(addr).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            client.send(&f).unwrap();
            client.recv().unwrap()
        });
        client
            .send(&Frame { seq: 0, message: Message::Control(splitfed::wire::Control::Shutdown) })
            .unwrap();
        echo.join().unwrap();
    }

    b.report();
}
