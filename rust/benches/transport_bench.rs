//! Transport + wire benches: framing overhead and link throughput for the
//! message sizes the paper's workloads actually generate, plus the mux
//! layer's per-frame overhead vs. the single-stream path. Emits
//! `BENCH_transport.json` at the repo root for the perf trajectory.
//!
//! Also owns the zero-copy data plane's allocation gate: after warmup the
//! steady-state mux send/recv loop must perform ZERO heap allocations per
//! step (every buffer rides the `BufPool` recycle circuit). The result is
//! merged into `BENCH_mem.json` and a nonzero count fails the bench
//! process, which fails CI.

use splitfed::bench_util::{merge_mem_json, Bench, CountingAlloc};
use splitfed::compress::Payload;
use splitfed::json::Json;
use splitfed::transport::sim::{LinkModel, SimNet};
use splitfed::transport::{FragPolicy, Mux, MuxConfig, MuxEvent, TcpTransport, Transport};
use splitfed::wire::{Frame, Message};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn frame_of(bytes: usize) -> Frame {
    Frame::new(
        1,
        Message::Activations {
            step: 1,
            payload: Payload::dense(32, bytes / 4 / 32, vec![0xAB; bytes]),
        },
    )
}

fn fast_net() -> SimNet {
    SimNet::new(LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 })
}

fn main() {
    // ---- allocation gate: steady-state mux data path --------------------
    // Runs first, while this is the only live thread, so the global
    // counter attributes every allocation to the loop under test. One
    // initiator -> acceptor stream over a fast sim link, lockstep
    // send/recv of a 16KiB Activations frame: after warmup every buffer
    // comes from the BufPool recycle circuit (encode take -> queue ->
    // recv share -> payload drop -> slot harvest), so the steady state
    // must not allocate at all.
    let gate_failed = {
        const WARMUP: usize = 256;
        const STEPS: u64 = 4096;
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::with_config(a, MuxConfig::initiator()).unwrap();
        let sm = Mux::with_config(bb, MuxConfig::acceptor()).unwrap();
        let mut cs = cm.open_stream().unwrap();
        assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
        let mut ss = sm.accept_stream(cs.id()).unwrap();
        let f = frame_of(16 * 1024);
        for _ in 0..WARMUP {
            cs.send(&f).unwrap();
            std::hint::black_box(ss.recv().unwrap());
        }
        let before = ALLOC.allocs();
        for _ in 0..STEPS {
            cs.send(&f).unwrap();
            std::hint::black_box(ss.recv().unwrap());
        }
        let allocs = ALLOC.allocs() - before;
        let per_step = allocs as f64 / STEPS as f64;
        println!(
            "steady-state mux path: {allocs} allocs over {STEPS} steps ({per_step:.4}/step)"
        );
        let mut m = BTreeMap::new();
        m.insert("case".to_string(), Json::Str("mux simlink 16KiB lockstep".to_string()));
        m.insert("warmup_steps".to_string(), Json::Num(WARMUP as f64));
        m.insert("steps".to_string(), Json::Num(STEPS as f64));
        m.insert("allocs".to_string(), Json::Num(allocs as f64));
        m.insert("allocs_per_step".to_string(), Json::Num(per_step));
        let mem_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_mem.json");
        match merge_mem_json(mem_out, "transport", Json::Obj(m)) {
            Ok(()) => println!("merged transport memory gate into {mem_out}"),
            Err(e) => eprintln!("failed to write {mem_out}: {e}"),
        }
        allocs > 0
    };

    let mut b = Bench::new("transport");
    b.min_time = 0.5;

    // wire encode/decode
    for size in [768usize, 16 * 1024, 160 * 1024] {
        let f = frame_of(size);
        let encoded = f.encode();
        b.run_bytes(&format!("frame encode {size}B"), size as u64, || f.encode());
        b.run_bytes(&format!("frame decode {size}B"), size as u64, || {
            Frame::decode(&encoded).unwrap()
        });
    }

    // sim link round trip (no network model cost, just queueing + codec)
    {
        let net = fast_net();
        let (mut a, mut bb) = net.pair();
        let f = frame_of(16 * 1024);
        b.run_bytes("simlink send+recv 16KiB", 16 * 1024, || {
            a.send(&f).unwrap();
            bb.recv().unwrap()
        });
    }

    // mux over the same sim link: measures demux + restamp + accounting
    // overhead relative to the single-stream case above
    {
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::with_config(a, MuxConfig::initiator()).unwrap();
        let sm = Mux::with_config(bb, MuxConfig::acceptor()).unwrap();
        let mut cs = cm.open_stream().unwrap();
        assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
        let mut ss = sm.accept_stream(cs.id()).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("mux simlink send+recv 16KiB (1 stream)", 16 * 1024, || {
            cs.send(&f).unwrap();
            ss.recv().unwrap()
        });
    }

    // mux with 8 interleaved streams: per-frame routing under contention
    {
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::with_config(a, MuxConfig::initiator()).unwrap();
        let sm = Mux::with_config(bb, MuxConfig::acceptor()).unwrap();
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..8 {
            let cs = cm.open_stream().unwrap();
            assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
            receivers.push(sm.accept_stream(cs.id()).unwrap());
            senders.push(cs);
        }
        let f = frame_of(16 * 1024);
        b.run_bytes("mux simlink 8-stream interleave 8x16KiB", 8 * 16 * 1024, || {
            for s in senders.iter_mut() {
                s.send(&f).unwrap();
            }
            for r in receivers.iter_mut() {
                r.recv().unwrap();
            }
        });
    }

    // TCP loopback round trip, single stream
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(stream);
            loop {
                match t.recv() {
                    Ok(f) => {
                        if matches!(f.message, Message::Control(_)) {
                            break;
                        }
                        t.send(&f).unwrap();
                    }
                    Err(_) => break,
                }
            }
        });
        let mut client = TcpTransport::connect(addr).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            client.send(&f).unwrap();
            client.recv().unwrap()
        });
        client
            .send(&Frame::new(0, Message::Control(splitfed::wire::Control::Shutdown)))
            .unwrap();
        echo.join().unwrap();
    }

    // mux over TCP loopback: the deployment path of serve_inference
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let sm = Mux::with_config(TcpTransport::from_stream(stream), MuxConfig::acceptor())
                .unwrap();
            let MuxEvent::Opened(id) = sm.next_event().unwrap() else {
                panic!("expected stream open");
            };
            let mut t = sm.accept_stream(id).unwrap();
            loop {
                match t.recv() {
                    Ok(f) => t.send(&f).unwrap(),
                    Err(_) => break, // CloseStream or hangup
                }
            }
        });
        let cm = Mux::with_config(TcpTransport::connect(addr).unwrap(), MuxConfig::initiator())
            .unwrap();
        let mut cs = cm.open_stream().unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("mux tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            cs.send(&f).unwrap();
            cs.recv().unwrap()
        });
        cs.close().unwrap();
        echo.join().unwrap();
    }

    b.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_transport.json");
    match b.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    // ---- fragmentation: BENCH_frag.json ---------------------------------
    let mut fb = Bench::new("frag");
    fb.min_time = 0.5;

    // fragmented vs whole delivery of the same 16KiB message over the
    // mux'd sim link: the delta is the per-fragment envelope encode,
    // extra frame headers, and reassembly-buffer append on the far side
    // (max_frame_size 1024 splits the frame ~17 ways).
    for frag in [None, Some(1024usize)] {
        let net = fast_net();
        let (a, bb) = net.pair();
        let mut ccfg = MuxConfig::initiator();
        let mut scfg = MuxConfig::acceptor();
        if let Some(max) = frag {
            ccfg = ccfg.fragmentation(FragPolicy::with_max_frame_size(max));
            scfg = scfg.fragmentation(FragPolicy::with_max_frame_size(max));
        }
        let cm = Mux::with_config(a, ccfg).unwrap();
        let sm = Mux::with_config(bb, scfg).unwrap();
        let mut cs = cm.open_stream().unwrap();
        assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
        let mut ss = sm.accept_stream(cs.id()).unwrap();
        let f = frame_of(16 * 1024);
        let name = match frag {
            None => "mux simlink 16KiB whole".to_string(),
            Some(max) => format!("mux simlink 16KiB frag max={max}"),
        };
        fb.run_bytes(&name, 16 * 1024, || {
            cs.send(&f).unwrap();
            ss.recv().unwrap()
        });
    }

    // head-of-line blocking over a real TCP connection: a mouse stream
    // echoes 512B frames while an elephant stream pushes 256KiB messages
    // down the same mux. Whole frames park the mouse behind a full
    // elephant write; fragmentation interleaves it after at most one
    // max_frame_size chunk. The p99 column is the paper-facing stall.
    for frag in [None, Some(4096usize)] {
        let samples = elephant_mouse_stall(frag);
        let name = match frag {
            None => "mouse echo p99 under elephant, whole frames".to_string(),
            Some(max) => format!("mouse echo p99 under elephant, frag max={max}"),
        };
        fb.record_samples(&name, &samples, None);
    }

    fb.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_frag.json");
    match fb.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    if gate_failed {
        eprintln!("\nALLOCATION GATE FAILED: the steady-state mux path allocated (want 0/step)");
        std::process::exit(1);
    }
}

/// Wall-clock ns per mouse echo roundtrip while an elephant stream
/// saturates the same mux'd TCP loopback connection with 256KiB frames.
fn elephant_mouse_stall(frag: Option<usize>) -> Vec<f64> {
    const ELEPHANT_BYTES: usize = 256 * 1024;
    const MOUSE_BYTES: usize = 512;
    const WARMUP: usize = 20;
    const SAMPLES: usize = 200;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut scfg = MuxConfig::acceptor();
        if let Some(max) = frag {
            scfg = scfg.fragmentation(FragPolicy::with_max_frame_size(max));
        }
        let sm = Mux::with_config(TcpTransport::from_stream(stream), scfg).unwrap();
        let mut opened = Vec::new();
        while opened.len() < 2 {
            if let MuxEvent::Opened(id) = sm.next_event().unwrap() {
                opened.push(id);
            }
        }
        opened.sort_unstable();
        let elephant = sm.accept_stream(opened[0]).unwrap();
        let mut mouse = sm.accept_stream(opened[1]).unwrap();
        let drain = std::thread::spawn(move || {
            let mut elephant = elephant;
            while elephant.recv().is_ok() {}
        });
        loop {
            match mouse.recv() {
                Ok(f) if matches!(f.message, Message::Control(_)) => break,
                Ok(f) => mouse.send(&f).unwrap(),
                Err(_) => break,
            }
        }
        drain.join().unwrap();
    });

    let mut ccfg = MuxConfig::initiator();
    if let Some(max) = frag {
        ccfg = ccfg.fragmentation(FragPolicy::with_max_frame_size(max));
    }
    let cm = Mux::with_config(TcpTransport::connect(addr).unwrap(), ccfg).unwrap();
    let es = cm.open_stream().unwrap();
    let mut ms = cm.open_stream().unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let elephant_stop = Arc::clone(&stop);
    let elephant = std::thread::spawn(move || {
        let mut es = es;
        let f = frame_of(ELEPHANT_BYTES);
        while !elephant_stop.load(Ordering::Relaxed) {
            es.send(&f).unwrap();
        }
        es
    });

    let f = frame_of(MOUSE_BYTES);
    for _ in 0..WARMUP {
        ms.send(&f).unwrap();
        ms.recv().unwrap();
    }
    let mut samples = Vec::with_capacity(SAMPLES);
    for _ in 0..SAMPLES {
        let t = Instant::now();
        ms.send(&f).unwrap();
        ms.recv().unwrap();
        samples.push(t.elapsed().as_nanos() as f64);
    }

    stop.store(true, Ordering::Relaxed);
    let mut es = elephant.join().unwrap();
    es.close().unwrap();
    ms.send(&Frame::new(0, Message::Control(splitfed::wire::Control::Shutdown))).unwrap();
    server.join().unwrap();
    samples
}
