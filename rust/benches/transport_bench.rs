//! Transport + wire benches: framing overhead and link throughput for the
//! message sizes the paper's workloads actually generate, plus the mux
//! layer's per-frame overhead vs. the single-stream path. Emits
//! `BENCH_transport.json` at the repo root for the perf trajectory.

use splitfed::bench_util::Bench;
use splitfed::compress::Payload;
use splitfed::transport::sim::{LinkModel, SimNet};
use splitfed::transport::{FragPolicy, Mux, MuxConfig, MuxEvent, TcpTransport, Transport};
use splitfed::wire::{Frame, Message};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn frame_of(bytes: usize) -> Frame {
    Frame::new(
        1,
        Message::Activations {
            step: 1,
            payload: Payload::dense(32, bytes / 4 / 32, vec![0xAB; bytes]),
        },
    )
}

fn fast_net() -> SimNet {
    SimNet::new(LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 })
}

fn main() {
    let mut b = Bench::new("transport");
    b.min_time = 0.5;

    // wire encode/decode
    for size in [768usize, 16 * 1024, 160 * 1024] {
        let f = frame_of(size);
        let encoded = f.encode();
        b.run_bytes(&format!("frame encode {size}B"), size as u64, || f.encode());
        b.run_bytes(&format!("frame decode {size}B"), size as u64, || {
            Frame::decode(&encoded).unwrap()
        });
    }

    // sim link round trip (no network model cost, just queueing + codec)
    {
        let net = fast_net();
        let (mut a, mut bb) = net.pair();
        let f = frame_of(16 * 1024);
        b.run_bytes("simlink send+recv 16KiB", 16 * 1024, || {
            a.send(&f).unwrap();
            bb.recv().unwrap()
        });
    }

    // mux over the same sim link: measures demux + restamp + accounting
    // overhead relative to the single-stream case above
    {
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::with_config(a, MuxConfig::initiator()).unwrap();
        let sm = Mux::with_config(bb, MuxConfig::acceptor()).unwrap();
        let mut cs = cm.open_stream().unwrap();
        assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
        let mut ss = sm.accept_stream(cs.id()).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("mux simlink send+recv 16KiB (1 stream)", 16 * 1024, || {
            cs.send(&f).unwrap();
            ss.recv().unwrap()
        });
    }

    // mux with 8 interleaved streams: per-frame routing under contention
    {
        let net = fast_net();
        let (a, bb) = net.pair();
        let cm = Mux::with_config(a, MuxConfig::initiator()).unwrap();
        let sm = Mux::with_config(bb, MuxConfig::acceptor()).unwrap();
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..8 {
            let cs = cm.open_stream().unwrap();
            assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
            receivers.push(sm.accept_stream(cs.id()).unwrap());
            senders.push(cs);
        }
        let f = frame_of(16 * 1024);
        b.run_bytes("mux simlink 8-stream interleave 8x16KiB", 8 * 16 * 1024, || {
            for s in senders.iter_mut() {
                s.send(&f).unwrap();
            }
            for r in receivers.iter_mut() {
                r.recv().unwrap();
            }
        });
    }

    // TCP loopback round trip, single stream
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(stream);
            loop {
                match t.recv() {
                    Ok(f) => {
                        if matches!(f.message, Message::Control(_)) {
                            break;
                        }
                        t.send(&f).unwrap();
                    }
                    Err(_) => break,
                }
            }
        });
        let mut client = TcpTransport::connect(addr).unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            client.send(&f).unwrap();
            client.recv().unwrap()
        });
        client
            .send(&Frame::new(0, Message::Control(splitfed::wire::Control::Shutdown)))
            .unwrap();
        echo.join().unwrap();
    }

    // mux over TCP loopback: the deployment path of serve_inference
    {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let sm = Mux::with_config(TcpTransport::from_stream(stream), MuxConfig::acceptor())
                .unwrap();
            let MuxEvent::Opened(id) = sm.next_event().unwrap() else {
                panic!("expected stream open");
            };
            let mut t = sm.accept_stream(id).unwrap();
            loop {
                match t.recv() {
                    Ok(f) => t.send(&f).unwrap(),
                    Err(_) => break, // CloseStream or hangup
                }
            }
        });
        let cm = Mux::with_config(TcpTransport::connect(addr).unwrap(), MuxConfig::initiator())
            .unwrap();
        let mut cs = cm.open_stream().unwrap();
        let f = frame_of(16 * 1024);
        b.run_bytes("mux tcp loopback roundtrip 16KiB", 2 * 16 * 1024, || {
            cs.send(&f).unwrap();
            cs.recv().unwrap()
        });
        cs.close().unwrap();
        echo.join().unwrap();
    }

    b.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_transport.json");
    match b.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    // ---- fragmentation: BENCH_frag.json ---------------------------------
    let mut fb = Bench::new("frag");
    fb.min_time = 0.5;

    // fragmented vs whole delivery of the same 16KiB message over the
    // mux'd sim link: the delta is the per-fragment envelope encode,
    // extra frame headers, and reassembly-buffer append on the far side
    // (max_frame_size 1024 splits the frame ~17 ways).
    for frag in [None, Some(1024usize)] {
        let net = fast_net();
        let (a, bb) = net.pair();
        let mut ccfg = MuxConfig::initiator();
        let mut scfg = MuxConfig::acceptor();
        if let Some(max) = frag {
            ccfg = ccfg.fragmentation(FragPolicy::with_max_frame_size(max));
            scfg = scfg.fragmentation(FragPolicy::with_max_frame_size(max));
        }
        let cm = Mux::with_config(a, ccfg).unwrap();
        let sm = Mux::with_config(bb, scfg).unwrap();
        let mut cs = cm.open_stream().unwrap();
        assert!(matches!(sm.next_event().unwrap(), MuxEvent::Opened(_)));
        let mut ss = sm.accept_stream(cs.id()).unwrap();
        let f = frame_of(16 * 1024);
        let name = match frag {
            None => "mux simlink 16KiB whole".to_string(),
            Some(max) => format!("mux simlink 16KiB frag max={max}"),
        };
        fb.run_bytes(&name, 16 * 1024, || {
            cs.send(&f).unwrap();
            ss.recv().unwrap()
        });
    }

    // head-of-line blocking over a real TCP connection: a mouse stream
    // echoes 512B frames while an elephant stream pushes 256KiB messages
    // down the same mux. Whole frames park the mouse behind a full
    // elephant write; fragmentation interleaves it after at most one
    // max_frame_size chunk. The p99 column is the paper-facing stall.
    for frag in [None, Some(4096usize)] {
        let samples = elephant_mouse_stall(frag);
        let name = match frag {
            None => "mouse echo p99 under elephant, whole frames".to_string(),
            Some(max) => format!("mouse echo p99 under elephant, frag max={max}"),
        };
        fb.record_samples(&name, &samples, None);
    }

    fb.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_frag.json");
    match fb.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}

/// Wall-clock ns per mouse echo roundtrip while an elephant stream
/// saturates the same mux'd TCP loopback connection with 256KiB frames.
fn elephant_mouse_stall(frag: Option<usize>) -> Vec<f64> {
    const ELEPHANT_BYTES: usize = 256 * 1024;
    const MOUSE_BYTES: usize = 512;
    const WARMUP: usize = 20;
    const SAMPLES: usize = 200;

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut scfg = MuxConfig::acceptor();
        if let Some(max) = frag {
            scfg = scfg.fragmentation(FragPolicy::with_max_frame_size(max));
        }
        let sm = Mux::with_config(TcpTransport::from_stream(stream), scfg).unwrap();
        let mut opened = Vec::new();
        while opened.len() < 2 {
            if let MuxEvent::Opened(id) = sm.next_event().unwrap() {
                opened.push(id);
            }
        }
        opened.sort_unstable();
        let elephant = sm.accept_stream(opened[0]).unwrap();
        let mut mouse = sm.accept_stream(opened[1]).unwrap();
        let drain = std::thread::spawn(move || {
            let mut elephant = elephant;
            while elephant.recv().is_ok() {}
        });
        loop {
            match mouse.recv() {
                Ok(f) if matches!(f.message, Message::Control(_)) => break,
                Ok(f) => mouse.send(&f).unwrap(),
                Err(_) => break,
            }
        }
        drain.join().unwrap();
    });

    let mut ccfg = MuxConfig::initiator();
    if let Some(max) = frag {
        ccfg = ccfg.fragmentation(FragPolicy::with_max_frame_size(max));
    }
    let cm = Mux::with_config(TcpTransport::connect(addr).unwrap(), ccfg).unwrap();
    let es = cm.open_stream().unwrap();
    let mut ms = cm.open_stream().unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let elephant_stop = Arc::clone(&stop);
    let elephant = std::thread::spawn(move || {
        let mut es = es;
        let f = frame_of(ELEPHANT_BYTES);
        while !elephant_stop.load(Ordering::Relaxed) {
            es.send(&f).unwrap();
        }
        es
    });

    let f = frame_of(MOUSE_BYTES);
    for _ in 0..WARMUP {
        ms.send(&f).unwrap();
        ms.recv().unwrap();
    }
    let mut samples = Vec::with_capacity(SAMPLES);
    for _ in 0..SAMPLES {
        let t = Instant::now();
        ms.send(&f).unwrap();
        ms.recv().unwrap();
        samples.push(t.elapsed().as_nanos() as f64);
    }

    stop.store(true, Ordering::Relaxed);
    let mut es = elephant.join().unwrap();
    es.close().unwrap();
    ms.send(&Frame::new(0, Message::Control(splitfed::wire::Control::Shutdown))).unwrap();
    server.join().unwrap();
    samples
}
