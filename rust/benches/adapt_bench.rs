//! Adaptation-plane bench: accuracy-per-byte of policy-driven codec
//! renegotiation (`compress::adapt` + mux `Respec`) against every static
//! spec on the ladder, over three SimNet link profiles (fast / slow /
//! lossy). Emits `BENCH_adapt.json` at the repo root.
//!
//! What is measured vs. modeled:
//!
//! - **wire bytes are REAL**: every run drives the full codec/wire/mux
//!   stack (recovery layer armed, faults injected on the lossy link), and
//!   the byte column is the physical link traffic including retransmits,
//!   acks, and the `Respec` handshake itself;
//! - **accuracy is MODELED**: a synthetic diminishing-returns curve
//!   (`modeled_gain`) stands in for the engine — per-epoch gain scales
//!   with the codec's fidelity `1 - exp(-k/3)` and decays as training
//!   saturates, the same shape the paper's Fig. 3 convergence curves
//!   show. The bench compares *policies*, not models, so the curve only
//!   needs to order specs correctly (more k = faster learning, more
//!   bytes).
//!
//! The gate: on the LOSSY link the adaptive run must beat the best
//! static spec on accuracy per megabyte, else the process exits 1 (CI
//! fails). Rationale: a lossy link inflates every frame with retransmit
//! traffic, so the policy's step-down (6 -> 4 -> 2) buys almost-equal
//! accuracy for far fewer bytes — if it ever stops doing that, the
//! adaptation plane has regressed.

use splitfed::compress::adapt::{self, AdaptPolicy, AdaptSignals};
use splitfed::compress::{codec_for, Batch, CodecSpec, Pass, SparseBatch};
use splitfed::config::Method;
use splitfed::json::Json;
use splitfed::metrics::{EpochRecord, RunLedger};
use splitfed::transport::sim::LinkModel;
use splitfed::transport::{
    FaultPlan, Mux, MuxConfig, MuxEvent, RecoveryPolicy, SimLink, SimNet, Transport,
};
use splitfed::wire::{Control, Frame, Message, OpenSpec};
use std::collections::BTreeMap;

const CUT: usize = 32;
const ROWS: usize = 4;
const EPOCHS: u32 = 6;
const STEPS_PER_EPOCH: u32 = 8;
/// Modeled accuracy ceiling.
const ACC_CAP: f64 = 0.95;
/// Per-epoch base learning gain: most learning happens early, which is
/// exactly when fidelity (k) matters — late epochs are cheap to sparsify.
const BASE_GAIN: [f64; EPOCHS as usize] = [0.5, 0.3, 0.15, 0.08, 0.05, 0.03];

/// Codec fidelity factor of the modeled gain: diminishing in k.
fn fidelity(m: Method) -> f64 {
    let level = adapt::method_level(m);
    if level <= 0.0 {
        1.0 // dense carries everything
    } else {
        1.0 - (-level / 3.0).exp()
    }
}

/// Deterministic synthetic cut-layer batch for a top-k family method
/// (no RNG: the bench compares policies on bytes, not on content).
fn batch_for(method: Method, step: u64) -> Batch {
    let k = method.k().expect("adapt bench drives the top-k family");
    let values = (0..ROWS * k)
        .map(|i| ((i as u64 + step * 7) % 17) as f32 * 0.1 - 0.8)
        .collect();
    let indices = (0..ROWS).flat_map(|_| 0..k as i32).collect();
    Batch::Sparse(SparseBatch { rows: ROWS, dim: CUT, k, values, indices })
}

/// The modeled label owner: decode forwards under the negotiated spec,
/// return scaled gradients, honour `Respec` proposals with the standard
/// step-keyed cut-over.
fn label_owner(mux: Mux<SimLink>) -> anyhow::Result<()> {
    let id = loop {
        match mux.next_event()? {
            MuxEvent::Opened(id) => break id,
            MuxEvent::Recovery(_) | MuxEvent::Flow(_) => continue,
            other => anyhow::bail!("label owner: unexpected pre-open event {other:?}"),
        }
    };
    let mut stream = mux.accept_stream(id)?;
    let Some(OpenSpec::Spec(spec0)) = mux.stream_spec(id) else {
        anyhow::bail!("stream {id} opened without a codec spec");
    };
    let mut codec = codec_for(spec0.method, spec0.cut_dim)?;
    let mut pending: Option<(u64, Method)> = None;
    let mut seq = 0u32;
    loop {
        let frame = stream.recv()?;
        match frame.message {
            Message::Activations { step, payload } => {
                if let Some((eff, m)) = pending {
                    if step >= eff {
                        codec = codec_for(m, spec0.cut_dim)?;
                        pending = None;
                    }
                }
                let decoded = codec.decode(&payload, Pass::Forward)?;
                let Batch::Sparse(act) = decoded else {
                    anyhow::bail!("label owner: expected a sparse batch");
                };
                let grad = Batch::Sparse(SparseBatch {
                    rows: act.rows,
                    dim: act.dim,
                    k: act.k,
                    values: act.values.iter().map(|v| v * 0.5).collect(),
                    indices: act.indices,
                });
                let payload = codec.encode(&grad, Pass::Backward)?;
                stream.send(&Frame::new(seq, Message::Gradients { step, payload }))?;
                seq += 1;
            }
            Message::Respec { effective_step, spec: OpenSpec::Spec(s), .. }
                if s.cut_dim == spec0.cut_dim && codec_for(s.method, s.cut_dim).is_ok() =>
            {
                mux.respec_accept(stream.id())?;
                pending = Some((effective_step, s.method));
            }
            Message::Respec { .. } => mux.respec_reject(stream.id())?,
            Message::Control(Control::Shutdown) => return Ok(()),
            other => anyhow::bail!("label owner: unexpected {:?}", other.msg_type()),
        }
    }
}

struct Outcome {
    /// Modeled final accuracy (see module doc: synthetic curve).
    accuracy: f64,
    /// REAL physical link bytes, both directions, incl. recovery traffic.
    wire_bytes: u64,
    /// Accepted renegotiations.
    switches: u64,
}

/// One training session: static when `policy` is `None`, adaptive
/// (decide at each epoch boundary, cut over at the epoch's first step)
/// when `Some`.
fn run_training(
    model: LinkModel,
    plan: FaultPlan,
    start: Method,
    policy: Option<&AdaptPolicy>,
) -> anyhow::Result<Outcome> {
    let net = SimNet::with_faults(model, plan);
    let (a, b) = net.pair();
    let rpolicy = RecoveryPolicy {
        probe_after_polls: 200,
        probe_interval_polls: 2_000,
        poll_timeout_ms: 30_000,
        ..RecoveryPolicy::default()
    };
    let nc = net.clone();
    let ns = net.clone();
    let cm = Mux::with_config(
        a,
        MuxConfig::initiator().recovery(rpolicy).reconnector(move |_| {
            nc.reconnect();
            Ok(None)
        }),
    )?;
    let sm = Mux::with_config(
        b,
        MuxConfig::acceptor().recovery(rpolicy).reconnector(move |_| {
            ns.reconnect();
            Ok(None)
        }),
    )?;
    let lo = std::thread::spawn(move || label_owner(sm));
    let mut stream = cm.open_stream_with(CodecSpec::new(start, CUT))?;
    let mut method = start;
    let mut codec = codec_for(method, CUT)?;
    let mut seq = 0u32;
    let mut acc = 0.0f64;
    let mut switches = 0u64;
    let mut step = 0u64;
    let mut ledger = RunLedger {
        config_text: format!("adapt bench start = {start}"),
        ..Default::default()
    };
    for epoch in 0..EPOCHS {
        if let (Some(p), true) = (policy, epoch > 0) {
            // signals from REAL telemetry: physical link stats + injected
            // fault totals + the ledger's loss slope
            let stats = cm.physical_stats();
            let faults = net.fault_totals();
            let secs = net.sim_secs();
            let sig = AdaptSignals {
                throughput: if secs > 0.0 { stats.total_bytes() as f64 / secs } else { 0.0 },
                fault_rate: (faults.total() as f64 / stats.frames_sent.max(1) as f64).min(1.0),
                buffered_bytes: cm.stream_window_used(stream.id()).unwrap_or(0),
                ..AdaptSignals::default()
            }
            .with_training(&ledger);
            if let Some(next) = p.decide(method, &sig) {
                // propose before encoding the boundary step; the await is
                // the cut-over barrier
                cm.respec_stream(stream.id(), CodecSpec::new(next, CUT), step)?;
                let accepted = cm.respec_await(stream.id())?;
                adapt::record_switch(&mut ledger, stream.id(), step, method, next, accepted);
                if accepted {
                    method = next;
                    codec = codec_for(method, CUT)?;
                    switches += 1;
                }
            }
        }
        for _ in 0..STEPS_PER_EPOCH {
            let batch = batch_for(method, step);
            let payload = codec.encode(&batch, Pass::Forward)?;
            stream.send(&Frame::new(seq, Message::Activations { step, payload }))?;
            seq += 1;
            let frame = stream.recv()?;
            let Message::Gradients { step: got, payload } = frame.message else {
                anyhow::bail!("expected Gradients, got {:?}", frame.message.msg_type());
            };
            anyhow::ensure!(got == step, "gradient step mismatch: {got} != {step}");
            std::hint::black_box(codec.decode(&payload, Pass::Backward)?);
            step += 1;
        }
        acc += (ACC_CAP - acc) * BASE_GAIN[epoch as usize] * fidelity(method);
        ledger.push(EpochRecord {
            epoch,
            train_loss: 1.0 - acc,
            train_metric: acc,
            test_loss: 1.0 - acc,
            test_metric: acc,
            comm_bytes: stream.stats().total_bytes(),
            sim_link_secs: net.sim_secs(),
            wall_secs: 0.0,
        });
    }
    // quiesce for the last frame (two generals), as the chaos harness does
    net.set_faults_enabled(false);
    stream.send(&Frame::new(seq, Message::Control(Control::Shutdown)))?;
    lo.join().map_err(|_| anyhow::anyhow!("label-owner thread panicked"))??;
    Ok(Outcome {
        accuracy: acc,
        wire_bytes: cm.physical_stats().total_bytes(),
        switches,
    })
}

struct Scenario {
    name: &'static str,
    model: LinkModel,
    plan: FaultPlan,
}

fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "fast",
            model: LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 },
            plan: FaultPlan::none(),
        },
        Scenario {
            name: "slow",
            model: LinkModel { bandwidth_bytes_per_sec: 50_000.0, latency_secs: 0.05 },
            plan: FaultPlan::none(),
        },
        Scenario {
            name: "lossy",
            model: LinkModel::default(),
            plan: FaultPlan {
                seed: 7,
                drop: 0.08,
                duplicate: 0.05,
                reorder: 0.05,
                corrupt: 0.04,
                truncate: 0.02,
                ..FaultPlan::default()
            },
        },
    ]
}

fn main() {
    let statics: Vec<Method> = AdaptPolicy::default()
        .k_ladder
        .iter()
        .map(|&k| Method::Topk { k })
        .collect();
    let policy = AdaptPolicy::default();
    let start = Method::Topk { k: 6 };

    let mut out_scenarios = Vec::new();
    let mut gate_pass = true;
    let mut gate_detail = BTreeMap::new();
    println!(
        "{:<8} {:<16} {:>10} {:>12} {:>12} {:>9}",
        "link", "spec", "accuracy", "wire bytes", "acc/MB", "switches"
    );
    for sc in scenarios() {
        let mut cases = Vec::new();
        let mut best_static: Option<(String, f64)> = None;
        for &m in &statics {
            let o = run_training(sc.model, sc.plan, m, None)
                .unwrap_or_else(|e| panic!("{} static {m}: {e:#}", sc.name));
            let apm = adapt::accuracy_per_mb(o.accuracy, o.wire_bytes);
            println!(
                "{:<8} {:<16} {:>10.4} {:>12} {:>12.2} {:>9}",
                sc.name, m.to_string(), o.accuracy, o.wire_bytes, apm, o.switches
            );
            if best_static.as_ref().map_or(true, |(_, b)| apm > *b) {
                best_static = Some((m.to_string(), apm));
            }
            cases.push(case_json(&m.to_string(), false, &o, apm));
        }
        let o = run_training(sc.model, sc.plan, start, Some(&policy))
            .unwrap_or_else(|e| panic!("{} adaptive: {e:#}", sc.name));
        let adaptive_apm = adapt::accuracy_per_mb(o.accuracy, o.wire_bytes);
        println!(
            "{:<8} {:<16} {:>10.4} {:>12} {:>12.2} {:>9}",
            sc.name, "adaptive", o.accuracy, o.wire_bytes, adaptive_apm, o.switches
        );
        cases.push(case_json("adaptive", true, &o, adaptive_apm));
        let (best_name, best_apm) = best_static.expect("at least one static spec");
        if sc.name == "lossy" {
            gate_pass = adaptive_apm > best_apm;
            gate_detail.insert("scenario".to_string(), Json::Str("lossy".into()));
            gate_detail.insert("adaptive_acc_per_mb".to_string(), Json::Num(adaptive_apm));
            gate_detail.insert("best_static".to_string(), Json::Str(best_name.clone()));
            gate_detail.insert("best_static_acc_per_mb".to_string(), Json::Num(best_apm));
            gate_detail.insert("pass".to_string(), Json::Bool(gate_pass));
        }
        let mut s = BTreeMap::new();
        s.insert("name".to_string(), Json::Str(sc.name.into()));
        s.insert("cases".to_string(), Json::Arr(cases));
        s.insert("best_static".to_string(), Json::Str(best_name));
        out_scenarios.push(Json::Obj(s));
    }

    let mut top = BTreeMap::new();
    top.insert("group".to_string(), Json::Str("adapt".into()));
    top.insert(
        "accuracy_model".to_string(),
        Json::Str(
            "accuracy is a synthetic diminishing-returns curve (gain scales with codec \
             fidelity 1-exp(-k/3), decaying per epoch); wire bytes are real measured link \
             traffic including recovery and Respec frames"
                .into(),
        ),
    );
    top.insert("scenarios".to_string(), Json::Arr(out_scenarios));
    top.insert("gate".to_string(), Json::Obj(gate_detail));
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_adapt.json");
    match std::fs::write(out, Json::Obj(top).to_string_pretty()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    if !gate_pass {
        eprintln!(
            "\nADAPT GATE FAILED: adaptive did not beat the best static spec on \
             accuracy-per-MB over the lossy link"
        );
        std::process::exit(1);
    }
}

fn case_json(spec: &str, adaptive: bool, o: &Outcome, apm: f64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("spec".to_string(), Json::Str(spec.into()));
    m.insert("adaptive".to_string(), Json::Bool(adaptive));
    m.insert("modeled_accuracy".to_string(), Json::Num(o.accuracy));
    m.insert("wire_bytes".to_string(), Json::Num(o.wire_bytes as f64));
    m.insert("acc_per_mb".to_string(), Json::Num(apm));
    m.insert("switches".to_string(), Json::Num(o.switches as f64));
    Json::Obj(m)
}
