//! PJRT artifact execution latency: per-artifact timings that make up one
//! split training step, for each model/variant. These are the numbers the
//! §Perf pass optimizes (EXPERIMENTS.md).

use std::sync::Arc;

use splitfed::bench_util::Bench;
use splitfed::config::Method;
use splitfed::coordinator::step_seed;
use splitfed::data::{for_model, Dataset, Split};
use splitfed::runtime::{default_artifacts_dir, Engine, HostTensor};
use xla::Literal;

fn main() {
    let engine = Arc::new(Engine::load(default_artifacts_dir()).expect("run `make artifacts`"));
    let mut b = Bench::new("runtime");
    b.min_time = 1.0;

    for model in ["mlp", "convnet", "textcnn", "gru4rec"] {
        let meta = engine.manifest.model(model).unwrap().clone();
        let k = meta.k_levels[meta.k_levels.len() / 2];
        let method = Method::RandTopk { k, alpha: 0.1 };
        let ds = for_model(model, meta.n_classes, 42, 256, 64).unwrap();
        let batch = ds.batch(Split::Train, &(0..meta.batch).collect::<Vec<_>>(), false);
        let (bottom, top) = engine.init_params(model, 1).unwrap();
        let mom_b = engine.zero_momentum(&meta.bottom_shapes).unwrap();
        let mom_t = engine.zero_momentum(&meta.top_shapes).unwrap();
        let x = batch.x.to_literal().unwrap();
        let y = HostTensor::i32(batch.y.clone(), &[meta.batch]).to_literal().unwrap();
        let seed = HostTensor::scalar_i32(step_seed(1, 1)).to_literal().unwrap();
        let alpha = HostTensor::vec1_f32(&[0.1]).to_literal().unwrap();
        let fixed = HostTensor::vec1_f32(&[0.0]).to_literal().unwrap();
        let lr = HostTensor::vec1_f32(&[0.05]).to_literal().unwrap();
        let variant = method.variant();

        // bottom_fwd (sparse)
        let key = format!("{model}/{variant}/bottom_fwd");
        let mut args: Vec<&Literal> = bottom.iter().collect();
        args.extend([&x, &seed, &alpha, &fixed]);
        let outs = engine.exec(&key, &args).unwrap();
        b.run(&format!("{model} bottom_fwd sparse_k{k}"), || {
            engine.exec(&key, &args).unwrap()
        });

        // dense bottom_fwd for comparison
        let dkey = format!("{model}/dense/bottom_fwd");
        let mut dargs: Vec<&Literal> = bottom.iter().collect();
        dargs.push(&x);
        b.run(&format!("{model} bottom_fwd dense"), || {
            engine.exec(&dkey, &dargs).unwrap()
        });

        // top_fwdbwd (sparse)
        let tkey = format!("{model}/{variant}/top_fwdbwd");
        let values = &outs[0];
        let indices = &outs[1];
        let mut targs: Vec<&Literal> = top.iter().chain(mom_t.iter()).collect();
        targs.extend([values, indices, &y, &lr]);
        let touts = engine.exec(&tkey, &targs).unwrap();
        b.run(&format!("{model} top_fwdbwd sparse_k{k}"), || {
            engine.exec(&tkey, &targs).unwrap()
        });

        // bottom_bwd (sparse)
        let bkey = format!("{model}/{variant}/bottom_bwd");
        let g_values = &touts[2 * top.len()];
        let mut bargs: Vec<&Literal> = bottom.iter().chain(mom_b.iter()).collect();
        bargs.extend([&x, indices, g_values, &lr]);
        b.run(&format!("{model} bottom_bwd sparse_k{k}"), || {
            engine.exec(&bkey, &bargs).unwrap()
        });
    }

    b.report();
    let s = engine.stats();
    println!(
        "\nengine totals: {} executions, mean {:.2} ms, {} compilations ({:.2} s)",
        s.executions,
        1e3 * s.exec_secs / s.executions.max(1) as f64,
        s.compilations,
        s.compile_secs
    );
}
