//! Codec throughput benches across the paper's (d, k/b) geometries,
//! constructed through the `codec_for` registry (the production path).
//! L3 perf target (DESIGN.md §7): dense >= 1 GiB/s, sparse pack >= 200
//! MiB/s — the codecs must never be the bottleneck next to model
//! execution.
//!
//! Also measures the encode-copy elimination end to end: the legacy path
//! (codec -> owned payload Vec -> `Frame::encode` copies it into the
//! frame buffer) vs the streamed path (`FrameEncoder` + `encode_into`,
//! codec output written straight into the frame buffer). Emits
//! `BENCH_codec.json` at the repo root for the perf trajectory.
//!
//! The word-wise kernel rewrite is measured against "before" paths kept
//! in this file (per-element f32 writes + the per-bit
//! `bitpack::reference` pack/unpack), and two gates run at the end:
//! steady-state `encode_into`/`decode_into` with reused buffers must not
//! allocate (merged into `BENCH_mem.json`), and throughput must clear the
//! committed floors in `BENCH_codec_baseline.json` — either failure exits
//! nonzero, which fails CI.

use splitfed::bench_util::{merge_mem_json, Bench, CountingAlloc};
use splitfed::compress::{codec_for, Batch, DenseBatch, Pass, QuantBatch, SparseBatch};
use splitfed::config::Method;
use splitfed::json::Json;
use splitfed::util::bitpack::{index_bits, reference};
use splitfed::util::Rng;
use splitfed::wire::{encode_payload_meta, Frame, FrameEncoder, Message, MsgType};
use std::collections::BTreeMap;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

/// The pre-kernel sparse encode: byte-at-a-time f32 copies plus the
/// per-bit reference writer. Layout-identical to the production path —
/// only the kernels differ.
fn sparse_encode_reference(batch: &SparseBatch, out: &mut Vec<u8>) {
    for v in &batch.values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let bits = index_bits(batch.dim);
    let mut w = reference::BitWriter::new();
    for &i in &batch.indices {
        w.write(i as u64, bits);
    }
    out.extend_from_slice(&w.into_bytes());
}

/// The pre-kernel sparse decode: per-element f32 reads plus the per-bit
/// reference reader.
fn sparse_decode_reference(
    bytes: &[u8],
    rows: usize,
    dim: usize,
    k: usize,
) -> (Vec<f32>, Vec<i32>) {
    let n = rows * k;
    let values: Vec<f32> = bytes[..n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let bits = index_bits(dim);
    let mut r = reference::BitReader::new(&bytes[n * 4..]);
    let indices: Vec<i32> = (0..n).map(|_| r.read(bits).unwrap() as i32).collect();
    (values, indices)
}

fn random_sparse(rng: &mut Rng, rows: usize, dim: usize, k: usize) -> SparseBatch {
    let mut values = Vec::new();
    let mut indices = Vec::new();
    for _ in 0..rows {
        let mut all: Vec<i32> = (0..dim as i32).collect();
        rng.shuffle(&mut all);
        let mut sel = all[..k].to_vec();
        sel.sort_unstable();
        for &i in &sel {
            indices.push(i);
            values.push(rng.normal());
        }
    }
    SparseBatch { rows, dim, k, values, indices }
}

fn main() {
    let rows = 32;
    let mut rng = Rng::new(42);
    let mut b = Bench::new("codec");

    for (d, k) in [(128usize, 6usize), (600, 14), (1280, 9)] {
        let codec = codec_for(Method::Topk { k }, d).unwrap();
        let sparse = random_sparse(&mut rng, rows, d, k);
        let batch = Batch::Sparse(sparse.clone());
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("sparse encode fwd d={d} k={k}"), dense_bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        // zero-copy path: content streamed into one reused buffer
        let mut buf = Vec::with_capacity(payload.wire_bytes());
        b.run_bytes(
            &format!("sparse encode_into fwd d={d} k={k} (reused buf)"),
            dense_bytes,
            || {
                buf.clear();
                codec.encode_into(&batch, Pass::Forward, &mut buf).unwrap();
            },
        );
        // the pre-kernel path, for the before/after delta in the report
        b.run_bytes(
            &format!("sparse encode fwd d={d} k={k} (per-bit reference)"),
            dense_bytes,
            || {
                buf.clear();
                sparse_encode_reference(&sparse, &mut buf);
            },
        );
        b.run_bytes(&format!("sparse decode fwd d={d} k={k}"), dense_bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
        // scratch-reusing decode: the production receive path
        let mut slot: Option<Batch> = None;
        b.run_bytes(
            &format!("sparse decode_into fwd d={d} k={k} (reused scratch)"),
            dense_bytes,
            || codec.decode_into(&payload, Pass::Forward, &mut slot).unwrap(),
        );
        b.run_bytes(
            &format!("sparse decode fwd d={d} k={k} (per-bit reference)"),
            dense_bytes,
            || sparse_decode_reference(&payload.bytes, rows, d, k),
        );
        let bwd = codec.encode(&batch, Pass::Backward).unwrap();
        b.run_bytes(&format!("sparse decode bwd d={d} k={k}"), dense_bytes, || {
            codec.decode(&bwd, Pass::Backward).unwrap()
        });
    }

    // the whole-frame comparison the refactor is about: one Activations
    // frame built with an intermediate payload copy vs streamed
    {
        let (d, k) = (1280usize, 9usize);
        let codec = codec_for(Method::Topk { k }, d).unwrap();
        let batch = Batch::Sparse(random_sparse(&mut rng, rows, d, k));
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("frame build copy path d={d} k={k}"), dense_bytes, || {
            let payload = codec.encode(&batch, Pass::Forward).unwrap();
            Frame::new(0, Message::Activations { step: 7, payload }).encode()
        });
        b.run_bytes(&format!("frame build streamed d={d} k={k}"), dense_bytes, || {
            let mut fe = FrameEncoder::new(0, 0, MsgType::Activations);
            fe.put_u64(7);
            encode_payload_meta(fe.body(), &codec.meta(rows, Pass::Forward));
            codec.encode_into(&batch, Pass::Forward, fe.body()).unwrap();
            fe.finish()
        });
    }

    for (d, bits) in [(128usize, 2u8), (1280, 4)] {
        let codec = codec_for(Method::Quant { bits }, d).unwrap();
        let levels = (1u64 << bits) as f32;
        let batch = Batch::Quant(QuantBatch {
            rows,
            dim: d,
            codes: (0..rows * d)
                .map(|_| (rng.next_f32() * levels).floor().min(levels - 1.0))
                .collect(),
            o_min: vec![-1.0; rows],
            o_max: vec![1.0; rows],
        });
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("quant encode d={d} b={bits}"), dense_bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        b.run_bytes(&format!("quant decode d={d} b={bits}"), dense_bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
    }

    for d in [128usize, 1280] {
        let codec = codec_for(Method::None, d).unwrap();
        let batch =
            Batch::Dense(DenseBatch::new(rows, d, (0..rows * d).map(|_| rng.normal()).collect()));
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("dense encode d={d}"), bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        let mut buf = Vec::with_capacity(payload.wire_bytes());
        b.run_bytes(&format!("dense encode_into d={d} (reused buf)"), bytes, || {
            buf.clear();
            codec.encode_into(&batch, Pass::Forward, &mut buf).unwrap();
        });
        // per-element "before" kernel for the f32 bulk-copy delta
        b.run_bytes(&format!("dense encode d={d} (per-element reference)"), bytes, || {
            buf.clear();
            if let Batch::Dense(db) = &batch {
                for v in &db.data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        });
        b.run_bytes(&format!("dense decode d={d}"), bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
        let mut slot: Option<Batch> = None;
        b.run_bytes(&format!("dense decode_into d={d} (reused scratch)"), bytes, || {
            codec.decode_into(&payload, Pass::Forward, &mut slot).unwrap()
        });
    }

    {
        let d = 600;
        let codec = codec_for(Method::L1 { lambda: 0.001, eps: 1e-4 }, d).unwrap();
        let data: Vec<f32> = (0..rows * d)
            .map(|_| if rng.next_f32() < 0.05 { rng.normal() } else { 0.0 })
            .collect();
        let batch = Batch::Dense(DenseBatch::new(rows, d, data));
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let bytes = (rows * d * 4) as u64;
        b.run_bytes("l1 encode d=600 (5% dense)", bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        b.run_bytes("l1 decode d=600 (5% dense)", bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
    }

    b.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_codec.json");
    match b.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    // ---- allocation gate: steady-state encode_into / decode_into --------
    // With a warm reused frame buffer and a persistent scratch batch, the
    // codec hot loop must not touch the allocator at all.
    let mut gate_failed = false;
    {
        const STEPS: u64 = 4096;
        let (d, k) = (1280usize, 9usize);
        let codec = codec_for(Method::Topk { k }, d).unwrap();
        let batch = Batch::Sparse(random_sparse(&mut rng, rows, d, k));
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let mut buf = Vec::with_capacity(payload.wire_bytes());
        let mut slot: Option<Batch> = None;
        // warm: first call sizes the buffer and the scratch vectors
        buf.clear();
        codec.encode_into(&batch, Pass::Forward, &mut buf).unwrap();
        codec.decode_into(&payload, Pass::Forward, &mut slot).unwrap();

        let before = ALLOC.allocs();
        for _ in 0..STEPS {
            buf.clear();
            codec.encode_into(&batch, Pass::Forward, &mut buf).unwrap();
        }
        let enc_allocs = ALLOC.allocs() - before;
        let before = ALLOC.allocs();
        for _ in 0..STEPS {
            codec.decode_into(&payload, Pass::Forward, &mut slot).unwrap();
        }
        let dec_allocs = ALLOC.allocs() - before;
        std::hint::black_box(&slot);
        println!(
            "steady-state codec d={d} k={k}: encode_into {enc_allocs} allocs / {STEPS} steps, \
             decode_into {dec_allocs} allocs / {STEPS} steps"
        );

        let mut m = BTreeMap::new();
        m.insert("case".to_string(), Json::Str(format!("topk d={d} k={k} rows={rows}")));
        m.insert("steps".to_string(), Json::Num(STEPS as f64));
        m.insert("encode_into_allocs".to_string(), Json::Num(enc_allocs as f64));
        m.insert(
            "encode_into_allocs_per_step".to_string(),
            Json::Num(enc_allocs as f64 / STEPS as f64),
        );
        m.insert("decode_into_allocs".to_string(), Json::Num(dec_allocs as f64));
        m.insert(
            "decode_into_allocs_per_step".to_string(),
            Json::Num(dec_allocs as f64 / STEPS as f64),
        );
        let mem_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_mem.json");
        match merge_mem_json(mem_out, "codec", Json::Obj(m)) {
            Ok(()) => println!("merged codec memory gate into {mem_out}"),
            Err(e) => eprintln!("failed to write {mem_out}: {e}"),
        }
        if enc_allocs > 0 || dec_allocs > 0 {
            eprintln!("ALLOCATION GATE FAILED: codec steady state allocated (want 0/step)");
            gate_failed = true;
        }
    }

    // ---- throughput floor gate vs the committed baseline ----------------
    // `BENCH_codec_baseline.json` carries conservative MiB/s floors (a
    // regression past 1.5x of a floor fails). Missing file = skip, so the
    // bench still runs on machines without the checkout layout.
    let baseline_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_codec_baseline.json");
    match std::fs::read_to_string(baseline_path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(base) => {
            let floors = base.get("floors_mib_per_s").and_then(|f| f.as_obj().cloned());
            for (case, floor) in floors.unwrap_or_default() {
                let Some(floor) = floor.as_f64() else { continue };
                let Some(r) = b.results.iter().find(|r| r.name == case) else {
                    eprintln!("baseline names unknown case {case:?}; skipping");
                    continue;
                };
                let Some(bytes) = r.bytes else { continue };
                let mib_s = bytes as f64 / (r.mean_ns / 1e9) / 1048576.0;
                if mib_s * 1.5 < floor {
                    eprintln!(
                        "THROUGHPUT GATE FAILED: {case}: {mib_s:.1} MiB/s is >1.5x below \
                         the {floor:.1} MiB/s floor"
                    );
                    gate_failed = true;
                } else {
                    println!("throughput floor ok: {case}: {mib_s:.1} MiB/s (floor {floor:.1})");
                }
            }
        }
        None => println!("no {baseline_path}; skipping throughput floor gate"),
    }

    if gate_failed {
        std::process::exit(1);
    }
}
