//! Codec throughput benches across the paper's (d, k/b) geometries,
//! constructed through the `codec_for` registry (the production path).
//! L3 perf target (DESIGN.md §7): dense >= 1 GiB/s, sparse pack >= 200
//! MiB/s — the codecs must never be the bottleneck next to model
//! execution.
//!
//! Also measures the encode-copy elimination end to end: the legacy path
//! (codec -> owned payload Vec -> `Frame::encode` copies it into the
//! frame buffer) vs the streamed path (`FrameEncoder` + `encode_into`,
//! codec output written straight into the frame buffer). Emits
//! `BENCH_codec.json` at the repo root for the perf trajectory.

use splitfed::bench_util::Bench;
use splitfed::compress::{codec_for, Batch, DenseBatch, Pass, QuantBatch, SparseBatch};
use splitfed::config::Method;
use splitfed::util::Rng;
use splitfed::wire::{encode_payload_meta, Frame, FrameEncoder, Message, MsgType};

fn random_sparse(rng: &mut Rng, rows: usize, dim: usize, k: usize) -> SparseBatch {
    let mut values = Vec::new();
    let mut indices = Vec::new();
    for _ in 0..rows {
        let mut all: Vec<i32> = (0..dim as i32).collect();
        rng.shuffle(&mut all);
        let mut sel = all[..k].to_vec();
        sel.sort_unstable();
        for &i in &sel {
            indices.push(i);
            values.push(rng.normal());
        }
    }
    SparseBatch { rows, dim, k, values, indices }
}

fn main() {
    let rows = 32;
    let mut rng = Rng::new(42);
    let mut b = Bench::new("codec");

    for (d, k) in [(128usize, 6usize), (600, 14), (1280, 9)] {
        let codec = codec_for(Method::Topk { k }, d).unwrap();
        let batch = Batch::Sparse(random_sparse(&mut rng, rows, d, k));
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("sparse encode fwd d={d} k={k}"), dense_bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        // zero-copy path: content streamed into one reused buffer
        let mut buf = Vec::with_capacity(payload.wire_bytes());
        b.run_bytes(
            &format!("sparse encode_into fwd d={d} k={k} (reused buf)"),
            dense_bytes,
            || {
                buf.clear();
                codec.encode_into(&batch, Pass::Forward, &mut buf).unwrap();
            },
        );
        b.run_bytes(&format!("sparse decode fwd d={d} k={k}"), dense_bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
        let bwd = codec.encode(&batch, Pass::Backward).unwrap();
        b.run_bytes(&format!("sparse decode bwd d={d} k={k}"), dense_bytes, || {
            codec.decode(&bwd, Pass::Backward).unwrap()
        });
    }

    // the whole-frame comparison the refactor is about: one Activations
    // frame built with an intermediate payload copy vs streamed
    {
        let (d, k) = (1280usize, 9usize);
        let codec = codec_for(Method::Topk { k }, d).unwrap();
        let batch = Batch::Sparse(random_sparse(&mut rng, rows, d, k));
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("frame build copy path d={d} k={k}"), dense_bytes, || {
            let payload = codec.encode(&batch, Pass::Forward).unwrap();
            Frame::new(0, Message::Activations { step: 7, payload }).encode()
        });
        b.run_bytes(&format!("frame build streamed d={d} k={k}"), dense_bytes, || {
            let mut fe = FrameEncoder::new(0, 0, MsgType::Activations);
            fe.put_u64(7);
            encode_payload_meta(fe.body(), &codec.meta(rows, Pass::Forward));
            codec.encode_into(&batch, Pass::Forward, fe.body()).unwrap();
            fe.finish()
        });
    }

    for (d, bits) in [(128usize, 2u8), (1280, 4)] {
        let codec = codec_for(Method::Quant { bits }, d).unwrap();
        let levels = (1u64 << bits) as f32;
        let batch = Batch::Quant(QuantBatch {
            rows,
            dim: d,
            codes: (0..rows * d)
                .map(|_| (rng.next_f32() * levels).floor().min(levels - 1.0))
                .collect(),
            o_min: vec![-1.0; rows],
            o_max: vec![1.0; rows],
        });
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("quant encode d={d} b={bits}"), dense_bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        b.run_bytes(&format!("quant decode d={d} b={bits}"), dense_bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
    }

    for d in [128usize, 1280] {
        let codec = codec_for(Method::None, d).unwrap();
        let batch =
            Batch::Dense(DenseBatch::new(rows, d, (0..rows * d).map(|_| rng.normal()).collect()));
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("dense encode d={d}"), bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        let mut buf = Vec::with_capacity(payload.wire_bytes());
        b.run_bytes(&format!("dense encode_into d={d} (reused buf)"), bytes, || {
            buf.clear();
            codec.encode_into(&batch, Pass::Forward, &mut buf).unwrap();
        });
        b.run_bytes(&format!("dense decode d={d}"), bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
    }

    {
        let d = 600;
        let codec = codec_for(Method::L1 { lambda: 0.001, eps: 1e-4 }, d).unwrap();
        let data: Vec<f32> = (0..rows * d)
            .map(|_| if rng.next_f32() < 0.05 { rng.normal() } else { 0.0 })
            .collect();
        let batch = Batch::Dense(DenseBatch::new(rows, d, data));
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let bytes = (rows * d * 4) as u64;
        b.run_bytes("l1 encode d=600 (5% dense)", bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        b.run_bytes("l1 decode d=600 (5% dense)", bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
    }

    b.report();

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_codec.json");
    match b.write_json(out) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }
}
