//! Codec throughput benches: encode/decode per method across the paper's
//! (d, k/b) geometries. L3 perf target (DESIGN.md §7): dense >= 1 GiB/s,
//! sparse pack >= 200 MiB/s — the codecs must never be the bottleneck next
//! to model execution.

use splitfed::bench_util::Bench;
use splitfed::compress::{
    quant::QuantBatch, DenseBatch, DenseCodec, L1Codec, Pass, QuantCodec, SparseBatch,
    SparseCodec,
};
use splitfed::util::Rng;

fn random_sparse(rng: &mut Rng, rows: usize, dim: usize, k: usize) -> SparseBatch {
    let mut values = Vec::new();
    let mut indices = Vec::new();
    for _ in 0..rows {
        let mut all: Vec<i32> = (0..dim as i32).collect();
        rng.shuffle(&mut all);
        let mut sel = all[..k].to_vec();
        sel.sort_unstable();
        for &i in &sel {
            indices.push(i);
            values.push(rng.normal());
        }
    }
    SparseBatch { rows, dim, k, values, indices }
}

fn main() {
    let rows = 32;
    let mut rng = Rng::new(42);
    let mut b = Bench::new("codec");

    for (d, k) in [(128usize, 6usize), (600, 14), (1280, 9)] {
        let codec = SparseCodec::topk(d, k);
        let batch = random_sparse(&mut rng, rows, d, k);
        let payload = codec.encode(&batch, Pass::Forward).unwrap();
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("sparse encode fwd d={d} k={k}"), dense_bytes, || {
            codec.encode(&batch, Pass::Forward).unwrap()
        });
        b.run_bytes(&format!("sparse decode fwd d={d} k={k}"), dense_bytes, || {
            codec.decode(&payload, Pass::Forward).unwrap()
        });
        let bwd = codec.encode(&batch, Pass::Backward).unwrap();
        b.run_bytes(&format!("sparse decode bwd d={d} k={k}"), dense_bytes, || {
            codec.decode(&bwd, Pass::Backward).unwrap()
        });
    }

    for (d, bits) in [(128usize, 2u8), (1280, 4)] {
        let codec = QuantCodec::new(d, bits);
        let levels = (1u64 << bits) as f32;
        let batch = QuantBatch {
            rows,
            dim: d,
            codes: (0..rows * d)
                .map(|_| (rng.next_f32() * levels).floor().min(levels - 1.0))
                .collect(),
            o_min: vec![-1.0; rows],
            o_max: vec![1.0; rows],
        };
        let payload = codec.encode(&batch).unwrap();
        let dense_bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("quant encode d={d} b={bits}"), dense_bytes, || {
            codec.encode(&batch).unwrap()
        });
        b.run_bytes(&format!("quant decode d={d} b={bits}"), dense_bytes, || {
            codec.decode(&payload).unwrap()
        });
    }

    for d in [128usize, 1280] {
        let codec = DenseCodec::new(d);
        let batch = DenseBatch::new(rows, d, (0..rows * d).map(|_| rng.normal()).collect());
        let payload = codec.encode(&batch).unwrap();
        let bytes = (rows * d * 4) as u64;
        b.run_bytes(&format!("dense encode d={d}"), bytes, || codec.encode(&batch).unwrap());
        b.run_bytes(&format!("dense decode d={d}"), bytes, || codec.decode(&payload).unwrap());
    }

    {
        let d = 600;
        let codec = L1Codec::new(d, 1e-4);
        let data: Vec<f32> = (0..rows * d)
            .map(|_| if rng.next_f32() < 0.05 { rng.normal() } else { 0.0 })
            .collect();
        let batch = DenseBatch::new(rows, d, data);
        let payload = codec.encode(&batch).unwrap();
        let bytes = (rows * d * 4) as u64;
        b.run_bytes("l1 encode d=600 (5% dense)", bytes, || codec.encode(&batch).unwrap());
        b.run_bytes("l1 decode d=600 (5% dense)", bytes, || codec.decode(&payload).unwrap());
    }

    b.report();
}
