//! Serving-plane bench: one reactor thread (`pump_conn`) driving large
//! stream rosters through per-stream credit windows over the sim link.
//! Three phases per roster size (32 / 1k / 10k streams):
//!
//! 1. every stream bursts past its window — the overflow parks
//!    client-side and the receiver's buffering is measured under full
//!    backpressure (the bounded-memory claim, in bytes);
//! 2. the roster is served to completion, echoing an `EvalResult` per
//!    request, for sustained requests/s on one core;
//! 3. individual request round trips are timed through the live roster
//!    for p50/p99 request latency.
//!
//! Emits `BENCH_serve.json` at the repo root and exits nonzero if p99
//! latency at 1k streams exceeds 1.5x the 32-stream baseline from the
//! same run, or if any roster's backpressure buffering exceeds the
//! credit-window bound `streams x (window + one frame)`.
//!
//! Also audits the global `BufPool` after the full 10k-stream walk: the
//! freelist and slot roster must still be inside their configured caps
//! (the recycle circuits are bounded, not a leak), merged into
//! `BENCH_mem.json` as the `serve` group and gated like the rest.

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use splitfed::bench_util::{fmt_ns, merge_mem_json, quantile_ns, CountingAlloc};
use splitfed::util::pool::{DEFAULT_FREE_CAP, DEFAULT_MAX_POOLED_BYTES, DEFAULT_SLOT_CAP};
use splitfed::util::BufPool;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();
use splitfed::compress::Payload;
use splitfed::coordinator::{pump_conn, PumpOutcome};
use splitfed::json::Json;
use splitfed::transport::sim::{LinkModel, SimLink, SimNet};
use splitfed::transport::{
    FlowPolicy, Mux, MuxConfig, MuxEvent, MuxStream, Transport, TransportError,
};
use splitfed::wire::{Frame, Message};

/// Per-stream credit window, sized so a 4-request burst overruns it and
/// must park (one request frame is ~300 wire bytes).
const WINDOW: u32 = 512;
const BURST: u64 = 4;
const SAMPLES: usize = 200;
const ROSTERS: [usize; 3] = [32, 1_000, 10_000];
const P99_RATIO_LIMIT: f64 = 1.5;
/// Below this absolute p99 the ratio gate is timer noise, not regression.
const P99_FLOOR_NS: f64 = 50_000.0;

fn request(step: u64) -> Frame {
    Frame::new(
        0,
        Message::Activations { step, payload: Payload::dense(4, 16, vec![0x5A; 4 * 16 * 4]) },
    )
}

fn echo_result(step: u64) -> Frame {
    Frame::new(0, Message::EvalResult { step, loss_sum: 0.0, metric_count: 0.0 })
}

fn is_would_block(e: &anyhow::Error) -> bool {
    TransportError::of(e) == Some(TransportError::WouldBlock)
}

/// Pop queued housekeeping events (`Flow`, `Data` already consumed by a
/// direct stream recv, ...) so the event queue stays flat between rounds.
fn drain_events<T: Transport>(mux: &Mux<T>) -> anyhow::Result<()> {
    loop {
        match mux.next_event() {
            Ok(_) => {}
            Err(e) if is_would_block(&e) => return Ok(()),
            Err(e) => return Err(e),
        }
    }
}

struct RosterStats {
    streams: usize,
    p50_ns: f64,
    p99_ns: f64,
    req_per_s: f64,
    buffered: u64,
    bound: u64,
}

fn run_roster(n: usize) -> anyhow::Result<RosterStats> {
    let net = SimNet::new(LinkModel { bandwidth_bytes_per_sec: 1e12, latency_secs: 0.0 });
    let (a, b) = net.pair();
    let policy = FlowPolicy::with_window(WINDOW);
    let cm = Mux::with_config(a, MuxConfig::initiator().flow_control(policy))?;
    let sm = Mux::with_config(b, MuxConfig::acceptor().flow_control(policy))?;

    let frame_len = request(0).encode().len() as u64;
    anyhow::ensure!(BURST * frame_len > WINDOW as u64, "burst must overrun the window");
    let bound = n as u64 * (WINDOW as u64 + frame_len);

    let mut clients: Vec<MuxStream<SimLink>> = Vec::with_capacity(n);
    for _ in 0..n {
        clients.push(cm.open_stream()?);
    }
    // phase 1: every stream bursts past its window; sends return
    // immediately (overflow parks in the credit queue), the wire and the
    // server inboxes stay window-bounded per stream
    for c in clients.iter_mut() {
        for step in 0..BURST {
            c.send(&request(step))?;
        }
    }
    // reactor pass with a route-only handler: frames land in per-stream
    // inboxes and STAY there — peak buffering under full backpressure
    let mut streams: HashMap<u32, MuxStream<SimLink>> = HashMap::with_capacity(n);
    {
        let mut route_only = |m: &Mux<SimLink>, ev: MuxEvent| -> anyhow::Result<bool> {
            if let MuxEvent::Opened(id) = ev {
                streams.insert(id, m.accept_stream(id)?);
            }
            Ok(false)
        };
        while !matches!(pump_conn(&sm, 4096, &mut route_only)?, PumpOutcome::Idle) {}
    }
    anyhow::ensure!(streams.len() == n, "accepted {} of {n} streams", streams.len());
    let buffered = sm.buffered_bytes();
    anyhow::ensure!(buffered > 0, "backpressure phase buffered nothing");

    // phase 2: serve the whole roster — consume, echo, let the grants pull
    // the parked overflow through
    let t0 = Instant::now();
    let target = n as u64 * BURST;
    let mut served = 0u64;
    while served < target {
        let mut progress = false;
        for s in streams.values_mut() {
            loop {
                match s.recv() {
                    Ok(f) => {
                        let Message::Activations { step, .. } = f.message else {
                            anyhow::bail!("unexpected request {:?}", f.message)
                        };
                        s.send(&echo_result(step))?;
                        served += 1;
                        progress = true;
                    }
                    Err(e) if is_would_block(&e) => break,
                    Err(e) => return Err(e),
                }
            }
        }
        drain_events(&sm)?;
        for c in clients.iter_mut() {
            loop {
                match c.recv() {
                    Ok(_) => progress = true,
                    Err(e) if is_would_block(&e) => break,
                    Err(e) => return Err(e),
                }
            }
        }
        drain_events(&cm)?;
        anyhow::ensure!(progress, "serving stalled at {served}/{target} requests");
    }
    let req_per_s = target as f64 / t0.elapsed().as_secs_f64();

    // phase 3: single-request round trips through the reactor pump while
    // the full roster stays live — per-request latency must not grow with
    // roster size
    let mut samples = Vec::with_capacity(SAMPLES);
    let mut echo = |_m: &Mux<SimLink>, ev: MuxEvent| -> anyhow::Result<bool> {
        if let MuxEvent::Data(id) = ev {
            if let Some(s) = streams.get_mut(&id) {
                let f = s.recv()?;
                let Message::Activations { step, .. } = f.message else {
                    anyhow::bail!("unexpected request {:?}", f.message)
                };
                s.send(&echo_result(step))?;
            }
        }
        Ok(false)
    };
    for i in 0..SAMPLES {
        let c = &mut clients[(i * 7919) % n];
        let t = Instant::now();
        c.send(&request(BURST + i as u64))?;
        let mut spins = 0u64;
        loop {
            pump_conn(&sm, 64, &mut echo)?;
            match c.recv() {
                Ok(_) => break,
                Err(e) if is_would_block(&e) => {
                    spins += 1;
                    anyhow::ensure!(spins < 1_000_000, "echo never arrived");
                }
                Err(e) => return Err(e),
            }
        }
        samples.push(t.elapsed().as_nanos() as f64);
    }

    // SAMPLES > 0 round trips always complete or bail above, so the
    // quantiles cannot be Empty; NaN would trip the ratio gate loudly
    Ok(RosterStats {
        streams: n,
        p50_ns: quantile_ns(&samples, 0.5).unwrap_or(f64::NAN),
        p99_ns: quantile_ns(&samples, 0.99).unwrap_or(f64::NAN),
        req_per_s,
        buffered,
        bound,
    })
}

fn main() {
    println!("== bench group: serve ==");
    let frame_len = request(0).encode().len() as u64;
    let mut rosters = Vec::new();
    for &n in &ROSTERS {
        let r = run_roster(n).unwrap_or_else(|e| panic!("roster {n}: {e:#}"));
        println!(
            "reactor @{:>6} streams: p50 {:>10}  p99 {:>10}  {:>9.0} req/s  backpressure {:>9} B (bound {} B)",
            r.streams,
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns),
            r.req_per_s,
            r.buffered,
            r.bound
        );
        rosters.push(r);
    }

    // gates: latency must not grow with roster size, buffering must stay
    // inside the credit-window bound
    let p99_32 = rosters[0].p99_ns;
    let p99_1k = rosters[1].p99_ns;
    let ratio = p99_1k / p99_32;
    let p99_ok = p99_1k <= P99_FLOOR_NS || ratio <= P99_RATIO_LIMIT;
    let buffer_ok = rosters.iter().all(|r| r.buffered <= r.bound);

    let mut top = BTreeMap::new();
    top.insert("group".to_string(), Json::Str("serve".to_string()));
    let mut reactor = BTreeMap::new();
    reactor.insert("cores".to_string(), Json::Num(1.0));
    reactor.insert(
        "sessions_per_core".to_string(),
        Json::Num(*ROSTERS.last().unwrap() as f64),
    );
    reactor.insert("flow_window_bytes".to_string(), Json::Num(WINDOW as f64));
    reactor.insert("request_frame_bytes".to_string(), Json::Num(frame_len as f64));
    reactor.insert("burst_per_stream".to_string(), Json::Num(BURST as f64));
    top.insert("reactor".to_string(), Json::Obj(reactor));
    top.insert(
        "rosters".to_string(),
        Json::Arr(
            rosters
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("streams".to_string(), Json::Num(r.streams as f64));
                    m.insert("p50_request_ns".to_string(), Json::Num(r.p50_ns));
                    m.insert("p99_request_ns".to_string(), Json::Num(r.p99_ns));
                    m.insert("requests_per_sec".to_string(), Json::Num(r.req_per_s));
                    m.insert(
                        "buffered_bytes_under_backpressure".to_string(),
                        Json::Num(r.buffered as f64),
                    );
                    m.insert("buffered_bound_bytes".to_string(), Json::Num(r.bound as f64));
                    Json::Obj(m)
                })
                .collect(),
        ),
    );
    let mut gates = BTreeMap::new();
    gates.insert("p99_ratio_limit".to_string(), Json::Num(P99_RATIO_LIMIT));
    gates.insert("p99_32_ns".to_string(), Json::Num(p99_32));
    gates.insert("p99_1k_ns".to_string(), Json::Num(p99_1k));
    gates.insert("p99_1k_vs_32_ratio".to_string(), Json::Num(ratio));
    gates.insert("p99_ok".to_string(), Json::Bool(p99_ok));
    gates.insert("buffer_bound_ok".to_string(), Json::Bool(buffer_ok));
    gates.insert("pass".to_string(), Json::Bool(p99_ok && buffer_ok));
    top.insert("gates".to_string(), Json::Obj(gates));

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match std::fs::write(out, Json::Obj(top).to_string_pretty()) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => eprintln!("\nfailed to write {out}: {e}"),
    }

    // pool boundedness after the 10k walk: every roster above pushed
    // frames through the global BufPool recycle circuits; whatever the
    // churn, the pool must still be inside its configured caps
    let ps = BufPool::global().stats();
    let pool_ok = ps.free <= DEFAULT_FREE_CAP
        && ps.slots <= DEFAULT_SLOT_CAP
        && ps.free_bytes <= DEFAULT_FREE_CAP * DEFAULT_MAX_POOLED_BYTES;
    println!(
        "global BufPool after 10k-stream walk: {} free ({} B retained), {} slots \
         (caps {DEFAULT_FREE_CAP}/{DEFAULT_SLOT_CAP})",
        ps.free, ps.free_bytes, ps.slots
    );
    let mut pm = BTreeMap::new();
    pm.insert("pool_free".to_string(), Json::Num(ps.free as f64));
    pm.insert("pool_free_bytes".to_string(), Json::Num(ps.free_bytes as f64));
    pm.insert("pool_slots".to_string(), Json::Num(ps.slots as f64));
    pm.insert("pool_free_cap".to_string(), Json::Num(DEFAULT_FREE_CAP as f64));
    pm.insert("pool_slot_cap".to_string(), Json::Num(DEFAULT_SLOT_CAP as f64));
    pm.insert("pool_bounded".to_string(), Json::Bool(pool_ok));
    pm.insert("process_allocs_total".to_string(), Json::Num(ALLOC.allocs() as f64));
    let mem_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_mem.json");
    match merge_mem_json(mem_out, "serve", Json::Obj(pm)) {
        Ok(()) => println!("merged serve pool audit into {mem_out}"),
        Err(e) => eprintln!("failed to write {mem_out}: {e}"),
    }
    if !pool_ok {
        eprintln!("GATE FAIL: global BufPool exceeded its configured caps after the roster walk");
    }

    if !buffer_ok {
        eprintln!("GATE FAIL: backpressure buffering exceeded streams x (window + frame)");
    }
    if !p99_ok {
        eprintln!(
            "GATE FAIL: p99 @1k streams is {:.2}x the 32-stream baseline (limit {P99_RATIO_LIMIT})",
            ratio
        );
    }
    if !(p99_ok && buffer_ok && pool_ok) {
        std::process::exit(1);
    }
}
