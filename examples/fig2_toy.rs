//! Figure 2 reproduction: the toy example showing top-k's bad local
//! minimum and how RandTopk escapes it (paper §4.2).
//!
//! Model: M_b(x1,x2) = (w1*x1, w2*x2), M_t(o1,o2) = tanh(o1+o2) with k=1
//! top-1 masking between them. Two samples: x1=(1,0) y=+1, x2=(0.5,1) y=-1.
//! Initial weights w = (1, -0.1). With top-1, o2 is always masked along the
//! trajectory, w2 never trains, and descent ends in the bad region; with
//! randomness (alpha > 0), w2 gets gradient and training escapes toward
//! w1 -> +inf, w2 -> -inf (loss -> 0).
//!
//! Outputs: runs/fig2/loss_surface.csv (grid), runs/fig2/traj_<m>.csv and
//! an ASCII rendering of the surface + trajectories.

use anyhow::Result;
use splitfed::util::Rng;

const SAMPLES: [([f32; 2], f32); 2] = [([1.0, 0.0], 1.0), ([0.5, 1.0], -1.0)];

/// Forward with top-1 masking; returns (loss, mask per sample).
/// Squared loss on tanh output.
fn loss(w: [f32; 2], masks: Option<&[usize; 2]>) -> (f32, [usize; 2]) {
    let mut total = 0.0;
    let mut used = [0usize; 2];
    for (i, ([x1, x2], y)) in SAMPLES.iter().enumerate() {
        let o = [w[0] * x1, w[1] * x2];
        // top-1 by |o| (or forced selection during randomized training)
        let sel = match masks {
            Some(m) => m[i],
            None => {
                if o[0].abs() >= o[1].abs() {
                    0
                } else {
                    1
                }
            }
        };
        used[i] = sel;
        let pred = o[sel].tanh();
        total += (pred - y) * (pred - y);
    }
    (total / 2.0, used)
}

/// Analytic gradient through the masked forward (selection frozen).
fn grad(w: [f32; 2], masks: &[usize; 2]) -> [f32; 2] {
    let mut g = [0.0f32; 2];
    for (i, ([x1, x2], y)) in SAMPLES.iter().enumerate() {
        let xs = [*x1, *x2];
        let sel = masks[i];
        let o = w[sel] * xs[sel];
        let t = o.tanh();
        // d/dw_sel of (tanh(w*x) - y)^2 / 2 (avg over 2 samples)
        g[sel] += (t - y) * (1.0 - t * t) * xs[sel] / 2.0;
    }
    g
}

fn descend(mut w: [f32; 2], alpha: f32, steps: usize, lr: f32, seed: u64) -> Vec<[f32; 2]> {
    let mut rng = Rng::new(seed);
    let mut traj = vec![w];
    for _ in 0..steps {
        let (_, topk_masks) = loss(w, None);
        // RandTopk with k=1 of d=2: with prob alpha select the non-top
        // element (Eq. 7)
        let masks = [
            if rng.next_f32() < alpha { 1 - topk_masks[0] } else { topk_masks[0] },
            if rng.next_f32() < alpha { 1 - topk_masks[1] } else { topk_masks[1] },
        ];
        let g = grad(w, &masks);
        w = [w[0] - lr * g[0], w[1] - lr * g[1]];
        traj.push(w);
    }
    traj
}

fn main() -> Result<()> {
    let dir = std::path::Path::new("runs/fig2");
    std::fs::create_dir_all(dir)?;

    // loss surface on [-2, 3] x [-3, 2]
    let n = 81;
    let mut csv = String::from("w1,w2,loss\n");
    for i in 0..n {
        for j in 0..n {
            let w1 = -2.0 + 5.0 * i as f32 / (n - 1) as f32;
            let w2 = -3.0 + 5.0 * j as f32 / (n - 1) as f32;
            let (l, _) = loss([w1, w2], None);
            csv.push_str(&format!("{w1},{w2},{l}\n"));
        }
    }
    std::fs::write(dir.join("loss_surface.csv"), csv)?;

    let start = [1.0f32, -0.1];
    let steps = 4000;
    let lr = 0.05;
    println!("Fig 2 toy example — start w = {start:?}, {steps} steps, lr = {lr}\n");
    println!("{:<22} {:>9} {:>9} {:>10}", "method", "w1_final", "w2_final", "final_loss");
    let mut results = Vec::new();
    for (name, alpha) in [("topk (alpha=0)", 0.0f32), ("randtopk alpha=0.1", 0.1), ("randtopk alpha=0.3", 0.3)] {
        let traj = descend(start, alpha, steps, lr, 7);
        let w = *traj.last().unwrap();
        let (l, _) = loss(w, None);
        println!("{:<22} {:>9.3} {:>9.3} {:>10.5}", name, w[0], w[1], l);
        let mut csv = String::from("step,w1,w2\n");
        for (s, w) in traj.iter().enumerate().step_by(20) {
            csv.push_str(&format!("{s},{},{}\n", w[0], w[1]));
        }
        let fname = format!("traj_{}.csv", name.replace([' ', '=', '(', ')'], "_"));
        std::fs::write(dir.join(fname), csv)?;
        results.push((name, w, l));
    }

    // the paper's claim, checked numerically:
    let topk_loss = results[0].2;
    let rand_loss = results[1].2;
    println!();
    if topk_loss > 0.4 && rand_loss < 0.1 {
        println!(
            "REPRODUCED: top-k is stuck at a bad local minimum (loss {topk_loss:.3}, w2 frozen at {:.3});",
            results[0].1[1]
        );
        println!(
            "RandTopk escapes (loss {rand_loss:.4}, w2 -> {:.2}) because non-top neurons receive gradient.",
            results[1].1[1]
        );
    } else {
        println!("WARNING: expected topk loss >~0.5 and randtopk loss ~0 (got {topk_loss:.3} / {rand_loss:.3})");
    }
    println!("\nwrote runs/fig2/loss_surface.csv and trajectory CSVs");
    Ok(())
}
