//! Appendix C reproduction (Fig 8): test accuracy as a function of the
//! randomness coefficient alpha, at a fixed compression level.
//!
//! ```bash
//! cargo run --release --example fig8_alpha -- --task mlp --epochs 8 --seeds 2
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::train;
use splitfed::metrics::mean_std;
use splitfed::runtime::{default_artifacts_dir, Engine};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);
    let task = args.get_or("task", "mlp").to_string();
    let epochs: u32 = args.get_parse("epochs")?.unwrap_or(8);
    let seeds: u64 = args.get_parse("seeds")?.unwrap_or(2);
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(4096);
    let lr: f32 = args.get_parse("lr")?.unwrap_or(match task.as_str() {
        "textcnn" | "gru4rec" => 0.3,
        "convnet" | "convnet_l" => 0.1,
        _ => 0.05,
    });

    let meta = engine.manifest.model(&task)?.clone();
    let k = meta.k_levels[0];

    println!("Fig 8 — {task}, k = {k}: accuracy vs alpha ({seeds} seeds, {epochs} epochs)\n");
    let alphas = [0.0f32, 0.05, 0.1, 0.2, 0.3, 0.5];
    let mut csv = String::from("alpha,acc_mean,acc_std\n");
    for alpha in alphas {
        let method = if alpha == 0.0 {
            Method::Topk { k }
        } else {
            Method::RandTopk { k, alpha }
        };
        let mut accs = Vec::new();
        for seed in 0..seeds {
            let mut cfg = ExperimentConfig::default();
            cfg.model = task.clone();
            cfg.method = method;
            cfg.epochs = epochs;
            cfg.n_train = n_train;
            cfg.n_test = n_train / 4;
            cfg.lr = lr;
            cfg.seed = 100 + seed;
            cfg.eval_every = epochs;
            let ledger = train(engine.clone(), cfg, false)?;
            accs.push(100.0 * ledger.final_metric());
        }
        let (m, s) = mean_std(&accs);
        println!("alpha={alpha:<5} acc = {m:.2} ({s:.2})");
        csv.push_str(&format!("{alpha},{m},{s}\n"));
    }
    let dir = std::path::Path::new("runs/fig8");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{task}.csv")), csv)?;
    println!("\npaper's claim: alpha in 0.05..0.3 beats alpha=0 (topk); too-large alpha degrades");
    println!("wrote runs/fig8/{task}.csv");
    Ok(())
}
