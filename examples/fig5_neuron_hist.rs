//! Figure 5 reproduction: distribution of top-k neuron selections at the
//! inference phase, after training with Topk vs RandTopk.
//!
//! After training, iterate the train set and count how many times each cut
//! neuron lands in the (deterministic) top-k. The paper's claim: training
//! with top-k leaves some neurons selected thousands of times and others
//! almost never; RandTopk balances the distribution.
//!
//! ```bash
//! cargo run --release --example fig5_neuron_hist -- --task mlp --epochs 8
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::Trainer;
use splitfed::data::{Dataset, EpochIter, Split};
use splitfed::runtime::{default_artifacts_dir, Engine};

fn gini(counts: &[u64]) -> f64 {
    // inequality measure of the selection distribution
    let mut xs: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for (i, x) in xs.iter().enumerate() {
        acc += (2.0 * (i as f64 + 1.0) - n - 1.0) * x;
    }
    acc / (n * sum)
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);
    let task = args.get_or("task", "mlp").to_string();
    let epochs: u32 = args.get_parse("epochs")?.unwrap_or(8);
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(4096);
    let lr: f32 = args.get_parse("lr")?.unwrap_or(0.05);

    let meta = engine.manifest.model(&task)?.clone();
    let k = meta.k_levels[0];
    let d = meta.cut_dim;

    let dir = std::path::Path::new("runs/fig5");
    std::fs::create_dir_all(dir)?;
    println!("Fig 5 — {task}, k = {k}, d = {d}: top-k neuron selection histogram\n");

    let mut csv = String::from("method,neuron,count\n");
    for (name, alpha) in [("topk", 0.0f32), ("randtopk_0.1", 0.1), ("randtopk_0.3", 0.3)] {
        let method = if alpha == 0.0 {
            Method::Topk { k }
        } else {
            Method::RandTopk { k, alpha }
        };
        let mut cfg = ExperimentConfig::default();
        cfg.model = task.clone();
        cfg.method = method;
        cfg.epochs = epochs;
        cfg.n_train = n_train;
        cfg.n_test = 512;
        cfg.lr = lr;
        cfg.seed = 42;
        cfg.eval_every = epochs;
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        trainer.run()?;

        // inference pass over the train set, counting selections
        let mut counts = vec![0u64; d];
        for indices in EpochIter::sequential(n_train, meta.batch) {
            let batch = trainer.dataset.batch(Split::Train, &indices, false);
            for idx in trainer.fo.selection_indices(&batch.x, k)? {
                counts[idx as usize] += 1;
            }
        }
        for (i, c) in counts.iter().enumerate() {
            csv.push_str(&format!("{name},{i},{c}\n"));
        }

        let never = counts.iter().filter(|&&c| c == 0).count();
        let max = *counts.iter().max().unwrap();
        let rare = counts.iter().filter(|&&c| c < (n_train / d) as u64 / 4).count();
        println!(
            "{name:<14} gini={:.3}  never-selected={never}/{d}  rarely={rare}  max={max}",
            gini(&counts)
        );
        // coarse ASCII histogram over count deciles
        let mut bins = [0usize; 10];
        let bin_w = (max as f64 / 10.0).max(1.0);
        for &c in &counts {
            bins[((c as f64 / bin_w) as usize).min(9)] += 1;
        }
        print!("  histogram (neurons per selection-count decile): ");
        for b in bins {
            print!("{b:>5}");
        }
        println!("\n");
    }
    std::fs::write(dir.join(format!("{task}.csv")), csv)?;
    println!("paper's claim: randtopk gini < topk gini, fewer never/rarely-selected neurons");
    println!("wrote runs/fig5/{task}.csv");
    Ok(())
}
