//! Quickstart: train a split MLP with RandTopk over the simulated link.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::Trainer;
use splitfed::runtime::{default_artifacts_dir, Engine};

fn main() -> Result<()> {
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);

    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.method = Method::parse("randtopk:k=6,alpha=0.1")?;
    cfg.epochs = 5;
    cfg.n_train = 4096;
    cfg.n_test = 1024;
    cfg.lr = 0.05;

    println!("training {} with {} ...", cfg.model, cfg.method);
    let mut trainer = Trainer::new(engine, cfg)?;
    trainer.verbose = true;
    let ledger = trainer.run()?;

    println!();
    println!("final test accuracy : {:.2}%", 100.0 * ledger.final_metric());
    println!(
        "total communication : {:.2} MiB (vs {:.2} MiB uncompressed)",
        ledger.total_comm_bytes() as f64 / 1048576.0,
        ledger.total_comm_bytes() as f64 / 1048576.0 * 100.0
            / ((ledger.fwd_compressed_pct + ledger.bwd_compressed_pct) / 2.0)
    );
    println!(
        "compressed size     : fwd {:.2}% / bwd {:.2}% of dense (paper Table 2: 5.71% / 4.69%)",
        ledger.fwd_compressed_pct, ledger.bwd_compressed_pct
    );
    Ok(())
}
