//! Multi-session inference service — the paper's deployment scenario
//! (§4.3: e.g. on-device face recognition where the label owner hosts the
//! top model), scaled out: N concurrent feature owners stream compressed
//! cut-layer activations over ONE multiplexed TCP connection to a single
//! label-owner process (one session registry, one shared Engine). Reports
//! aggregate and per-session throughput / latency / exact wire traffic,
//! and asserts that per-session `LinkStats` sum exactly to the physical
//! connection's byte counts.
//!
//! ```bash
//! cargo run --release --example serve_inference -- --clients 8 --requests 16
//! ```

use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::Method;
use splitfed::coordinator::serve::{
    eval_indices, serve_tcp, EVAL_INIT_SEED, EVAL_N_TEST, EVAL_N_TRAIN,
};
use splitfed::coordinator::FeatureOwner;
use splitfed::data::{for_model, Dataset, Split};
use splitfed::runtime::{default_artifacts_dir, Engine};
use splitfed::transport::{LinkStats, Mux, TcpTransport, Transport};
use splitfed::util::timer::Stats;

struct ClientResult {
    stream_id: u32,
    lat: Stats,
    correct: f32,
    samples: usize,
    fwd_pct: f64,
    stats: LinkStats,
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let clients: usize = args.get_parse("clients")?.unwrap_or(4).max(1);
    let requests: usize = args.get_parse("requests")?.unwrap_or(16).max(1);
    let model = args.get_or("model", "mlp").to_string();
    let method = Method::parse(args.get_or("method", "randtopk:k=6,alpha=0.1"))?;
    let seed = 42u64;

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let dir = default_artifacts_dir();

    // one physical connection; the server demuxes all sessions off it
    let phys = TcpTransport::connect(addr)?;
    let mut server = serve_tcp(&listener, 1, dir.clone(), model.clone(), method, seed)?;
    let mux = Mux::initiator(phys);

    let t_all = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..clients {
        let mux = mux.clone();
        let dir = dir.clone();
        let model = model.clone();
        handles.push(std::thread::spawn(move || -> Result<ClientResult> {
            let engine = Rc::new(Engine::load(&dir)?);
            let stream = mux.open_stream()?;
            let stream_id = stream.id();
            let mut fo = FeatureOwner::new(engine, &model, method, stream, seed, EVAL_INIT_SEED)?;
            // geometry shared with MuxServer so server-derived labels align
            let ds = for_model(&model, fo.meta.n_classes, seed, EVAL_N_TRAIN, EVAL_N_TEST);
            let n_test = ds.len(Split::Test);
            let b = fo.meta.batch;
            let mut lat = Stats::new();
            let mut correct = 0.0f32;
            let mut samples = 0usize;
            for req in 0..requests {
                let idx = eval_indices(req as u64, b, n_test);
                let batch = ds.batch(Split::Test, &idx, false);
                let t0 = Instant::now();
                fo.eval_forward(req as u64, &batch.x)?;
                let (_, c) = fo.recv_eval_result()?;
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                correct += c;
                samples += b;
            }
            fo.transport.close()?;
            let stats = fo.transport.stats();
            let dense_bytes = (requests * b * fo.meta.cut_dim * 4) as f64;
            Ok(ClientResult {
                stream_id,
                lat,
                correct,
                samples,
                fwd_pct: 100.0 * stats.bytes_sent as f64 / dense_bytes,
                stats,
            })
        }));
    }

    let mut results: Vec<ClientResult> = Vec::new();
    for h in handles {
        results.push(h.join().expect("client thread panicked")?);
    }
    let total = t_all.elapsed().as_secs_f64();
    results.sort_by_key(|r| r.stream_id);

    // all sessions are closed; read the physical counters, then hang up so
    // the server's event pump sees EOF and finishes the connection
    let phys = mux.physical_stats();
    drop(mux);
    let report = server.pop().expect("server handle").join().expect("server thread panicked")?;

    println!(
        "serve_inference — {model} + {method}, {clients} sessions x {requests} requests, one connection"
    );
    println!(
        "  {:<8} {:>9} {:>11} {:>11} {:>11} {:>11} {:>9}",
        "session", "requests", "mean ms", "max ms", "sent KiB", "recv KiB", "acc %"
    );
    for r in &results {
        println!(
            "  {:<8} {:>9} {:>11.2} {:>11.2} {:>11.1} {:>11.1} {:>9.2}",
            r.stream_id,
            r.lat.n,
            r.lat.mean(),
            r.lat.max,
            r.stats.bytes_sent as f64 / 1024.0,
            r.stats.bytes_recv as f64 / 1024.0,
            100.0 * r.correct as f64 / r.samples as f64,
        );
    }

    let samples: usize = results.iter().map(|r| r.samples).sum();
    let reqs: usize = clients * requests;
    println!(
        "  aggregate  : {:.0} samples/s ({:.1} req/s) over {} sessions",
        samples as f64 / total,
        reqs as f64 / total,
        clients
    );
    println!(
        "  wire       : sent {:.1} KiB ({:.2}% of dense activations), recv {:.1} KiB on one connection",
        phys.bytes_sent as f64 / 1024.0,
        results.iter().map(|r| r.fwd_pct).sum::<f64>() / results.len() as f64,
        phys.bytes_recv as f64 / 1024.0
    );

    // --- invariants -------------------------------------------------------
    // per-session stats sum exactly to the physical connection, both ends
    let sum_sent: u64 = results.iter().map(|r| r.stats.bytes_sent).sum();
    let sum_recv: u64 = results.iter().map(|r| r.stats.bytes_recv).sum();
    assert_eq!(sum_sent, phys.bytes_sent, "client session stats must sum to physical sent");
    assert_eq!(sum_recv, phys.bytes_recv, "client session stats must sum to physical recv");
    assert_eq!(
        report.session_bytes_recv(),
        report.physical.bytes_recv,
        "server session stats must sum to physical recv"
    );
    assert_eq!(
        report.session_bytes_sent(),
        report.physical.bytes_sent,
        "server session stats must sum to physical sent"
    );
    assert_eq!(phys.bytes_sent, report.physical.bytes_recv, "both ends agree on the wire");
    assert_eq!(report.total_requests(), reqs as u64);

    // every session runs the same eval stream against the same model, so
    // accuracy must be identical across sessions (== the single-client run)
    let acc0 = 100.0 * results[0].correct as f64 / results[0].samples as f64;
    for r in &results {
        let acc = 100.0 * r.correct as f64 / r.samples as f64;
        assert!((acc - acc0).abs() < 1e-9, "session {} accuracy {acc} != {acc0}", r.stream_id);
    }
    println!("  accuracy   : {acc0:.2}% on {} samples/session (identical across sessions)", results[0].samples);
    Ok(())
}
