//! Multi-session inference service — the paper's deployment scenario
//! (§4.3: e.g. on-device face recognition where the label owner hosts the
//! top model), scaled out AND heterogeneous: N concurrent feature owners,
//! each with its OWN compression method, stream compressed cut-layer
//! activations over ONE multiplexed TCP connection to a single
//! label-owner process. Every stream's `OpenStream` carries a
//! `CodecSpec`; the server builds each session's `LabelOwner` from the
//! negotiated spec (one session registry, one shared Engine). Reports
//! aggregate and per-session throughput / latency / exact wire traffic,
//! asserts that per-session `LinkStats` sum exactly to the physical
//! connection's byte counts, and pins every session's traffic to the
//! byte against its codec's `expected_wire_bytes`.
//!
//! ```bash
//! cargo run --release --example serve_inference -- --clients 3 \
//!     --methods "randtopk:k=6,alpha=0.1;quant:bits=2;none"
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::compress::{codec_for, CodecSpec, Pass};
use splitfed::config::Method;
use splitfed::coordinator::serve::{
    eval_indices, EVAL_INIT_SEED, EVAL_N_TEST, EVAL_N_TRAIN,
};
use splitfed::coordinator::{FeatureOwner, MuxServer, ServeOptions};
use splitfed::data::{for_model, Dataset, Split};
use splitfed::runtime::{default_artifacts_dir, Engine};
use splitfed::transport::{LinkStats, Mux, MuxConfig, TcpTransport, Transport};
use splitfed::util::timer::Stats;
use splitfed::wire::{payload_meta_wire_len, Frame, Message, OpenSpec, HEADER_BYTES};

struct ClientResult {
    stream_id: u32,
    method: Method,
    lat: Stats,
    correct: f32,
    samples: usize,
    fwd_pct: f64,
    stats: LinkStats,
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let clients: usize = args.get_parse("clients")?.unwrap_or(4).max(1);
    let requests: usize = args.get_parse("requests")?.unwrap_or(16).max(1);
    let model = args.get_or("model", "mlp").to_string();
    let seed = 42u64;

    // ONE engine shared by every client thread AND the server (the engine
    // is Send + Sync, so N sessions cost one compile per artifact, not N)
    let dir = default_artifacts_dir();
    let engine = Arc::new(Engine::load(&dir)?);
    let meta = engine.manifest.model(&model)?.clone();
    let cut_dim = meta.cut_dim;

    let methods: Vec<Method> = if let Some(spec) = args.get("methods") {
        spec.split(';')
            .filter(|s| !s.trim().is_empty())
            .map(|s| Method::parse(s.trim()))
            .collect::<Result<_>>()?
    } else if let Some(one) = args.get("method") {
        vec![Method::parse(one)?]
    } else {
        // default: one of each family the manifest has artifacts for
        let mut v = Vec::new();
        if let Some(&k) = meta.k_levels.get(1).or_else(|| meta.k_levels.first()) {
            v.push(Method::RandTopk { k, alpha: 0.1 });
        }
        if let Some(&bits) = meta.quant_bits.first() {
            v.push(Method::Quant { bits: bits as u8 });
        }
        v.push(Method::None);
        v
    };
    anyhow::ensure!(!methods.is_empty(), "--methods parsed to an empty list");

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;

    // one physical connection; the server demuxes all sessions off it and
    // negotiates each session's codec from its OpenStream spec
    let phys = TcpTransport::connect(addr)?;
    let server = Arc::new(MuxServer::new(engine.clone(), &model, methods[0], seed))
        .serve(listener, ServeOptions::default())?;
    let mux = Mux::with_config(phys, MuxConfig::initiator())?;

    let t_all = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let method = methods[c % methods.len()];
        let mux = mux.clone();
        let engine = engine.clone();
        let model = model.clone();
        handles.push(std::thread::spawn(move || -> Result<ClientResult> {
            let spec = CodecSpec::new(method, cut_dim);
            let stream = mux.open_stream_with(spec)?;
            let stream_id = stream.id();
            let mut fo = FeatureOwner::new(engine, &model, method, stream, seed, EVAL_INIT_SEED)?;
            // geometry shared with MuxServer so server-derived labels align
            let ds = for_model(&model, fo.meta.n_classes, seed, EVAL_N_TRAIN, EVAL_N_TEST)?;
            let n_test = ds.len(Split::Test);
            let b = fo.meta.batch;
            let mut lat = Stats::new();
            let mut correct = 0.0f32;
            let mut samples = 0usize;
            for req in 0..requests {
                let idx = eval_indices(req as u64, b, n_test);
                let batch = ds.batch(Split::Test, &idx, false);
                let t0 = Instant::now();
                fo.eval_forward(req as u64, &batch.x)?;
                let (_, c) = fo.recv_eval_result()?;
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                correct += c;
                samples += b;
            }
            fo.transport.close()?;
            let stats = fo.transport.stats();

            // --- exact per-stream byte accounting -------------------------
            // sent = OpenStream(spec) + requests * Activations + CloseStream,
            // each predicted to the byte from the codec registry
            let codec = codec_for(method, cut_dim)?;
            if let Some(content) = codec.expected_wire_bytes(b, Pass::Forward) {
                let meta_len = payload_meta_wire_len(&codec.meta(b, Pass::Forward));
                let open_len = Frame::on_stream(
                    stream_id,
                    0,
                    Message::OpenStream { spec: OpenSpec::Spec(spec) },
                )
                .wire_len();
                let per_req = HEADER_BYTES + 8 + meta_len + content;
                let close_len = HEADER_BYTES; // CloseStream has an empty body
                let expect_sent = (open_len + requests * per_req + close_len) as u64;
                assert_eq!(
                    stats.bytes_sent, expect_sent,
                    "session {stream_id} ({method}): sent bytes must match the codec model"
                );
            }
            // recv = requests * EvalResult (step u64 + two f32)
            let expect_recv = (requests * (HEADER_BYTES + 16)) as u64;
            assert_eq!(stats.bytes_recv, expect_recv, "session {stream_id}: recv bytes");

            let dense_bytes = (requests * b * cut_dim * 4) as f64;
            Ok(ClientResult {
                stream_id,
                method,
                lat,
                correct,
                samples,
                fwd_pct: 100.0 * stats.bytes_sent as f64 / dense_bytes,
                stats,
            })
        }));
    }

    let mut results: Vec<ClientResult> = Vec::new();
    for h in handles {
        results.push(h.join().expect("client thread panicked")?);
    }
    let total = t_all.elapsed().as_secs_f64();
    results.sort_by_key(|r| r.stream_id);

    // all sessions are closed; read the physical counters, then hang up so
    // the server's event pump sees EOF and finishes the connection
    let phys = mux.physical_stats();
    drop(mux);
    let report = server.join()?.pop().expect("one connection report");

    println!(
        "serve_inference — {model}, {clients} heterogeneous sessions x {requests} requests, one connection"
    );
    println!(
        "  {:<8} {:<26} {:>9} {:>9} {:>9} {:>11} {:>11} {:>8}",
        "session", "method", "requests", "mean ms", "max ms", "sent KiB", "recv KiB", "acc %"
    );
    for r in &results {
        println!(
            "  {:<8} {:<26} {:>9} {:>9.2} {:>9.2} {:>11.1} {:>11.1} {:>8.2}",
            r.stream_id,
            r.method.to_string(),
            r.lat.n,
            r.lat.mean(),
            r.lat.max,
            r.stats.bytes_sent as f64 / 1024.0,
            r.stats.bytes_recv as f64 / 1024.0,
            100.0 * r.correct as f64 / r.samples as f64,
        );
    }

    let samples: usize = results.iter().map(|r| r.samples).sum();
    let reqs: usize = clients * requests;
    println!(
        "  aggregate  : {:.0} samples/s ({:.1} req/s) over {} sessions",
        samples as f64 / total,
        reqs as f64 / total,
        clients
    );
    println!(
        "  wire       : sent {:.1} KiB ({:.2}% of dense activations), recv {:.1} KiB on one connection",
        phys.bytes_sent as f64 / 1024.0,
        results.iter().map(|r| r.fwd_pct).sum::<f64>() / results.len() as f64,
        phys.bytes_recv as f64 / 1024.0
    );
    println!(
        "  engine     : {} compilations ({:.2}s) — warmed at startup, shared by all sessions",
        report.compilations, report.compile_secs
    );

    // --- invariants -------------------------------------------------------
    // per-session stats sum exactly to the physical connection, both ends
    let sum_sent: u64 = results.iter().map(|r| r.stats.bytes_sent).sum();
    let sum_recv: u64 = results.iter().map(|r| r.stats.bytes_recv).sum();
    assert_eq!(sum_sent, phys.bytes_sent, "client session stats must sum to physical sent");
    assert_eq!(sum_recv, phys.bytes_recv, "client session stats must sum to physical recv");
    assert_eq!(
        report.session_bytes_recv(),
        report.physical.bytes_recv,
        "server session stats must sum to physical recv"
    );
    assert_eq!(
        report.session_bytes_sent(),
        report.physical.bytes_sent,
        "server session stats must sum to physical sent"
    );
    assert_eq!(phys.bytes_sent, report.physical.bytes_recv, "both ends agree on the wire");
    assert_eq!(report.total_requests(), reqs as u64);
    assert!(report.refused.is_empty(), "no stream should be refused: {:?}", report.refused);

    // the server must have honoured each session's negotiated method
    let by_id: HashMap<u32, Method> =
        report.sessions.iter().map(|s| (s.stream_id, s.method)).collect();
    for r in &results {
        assert_eq!(by_id.get(&r.stream_id), Some(&r.method), "server ran the negotiated codec");
    }

    // sessions sharing a method run the same eval stream against the same
    // model, so their accuracy must be identical
    let mut acc_by_method: HashMap<String, f64> = HashMap::new();
    for r in &results {
        let acc = 100.0 * r.correct as f64 / r.samples as f64;
        let entry = acc_by_method.entry(r.method.to_string()).or_insert(acc);
        assert!(
            (*entry - acc).abs() < 1e-9,
            "sessions with method {} disagree: {acc} != {entry}",
            r.method
        );
    }
    println!(
        "  accuracy   : {} (identical across sessions sharing a method)",
        acc_by_method
            .iter()
            .map(|(m, a)| format!("{m}={a:.2}%"))
            .collect::<Vec<_>>()
            .join("  ")
    );
    Ok(())
}
