//! Two-party inference service — the paper's deployment scenario (§4.3:
//! e.g. on-device face recognition where the label owner hosts the top
//! model). The feature owner streams compressed cut-layer activations for
//! eval batches over TCP; the label owner answers with loss/metric; we
//! report request latency and throughput plus the exact wire traffic.
//!
//! ```bash
//! cargo run --release --example serve_inference -- --requests 64
//! ```

use std::rc::Rc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::Method;
use splitfed::coordinator::{FeatureOwner, LabelOwner};
use splitfed::data::{for_model, Split};
use splitfed::runtime::{default_artifacts_dir, Engine};
use splitfed::transport::{TcpTransport, Transport};
use splitfed::util::timer::Stats;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let requests: usize = args.get_parse("requests")?.unwrap_or(64);
    let model = args.get_or("model", "mlp").to_string();
    let method = Method::parse(args.get_or("method", "randtopk:k=6,alpha=0.1"))?;
    let seed = 42u64;

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let dir = default_artifacts_dir();

    // label owner: the serving party
    let dir_lo = dir.clone();
    let model_lo = model.clone();
    let server = std::thread::spawn(move || -> Result<u64> {
        let engine = Rc::new(Engine::load(&dir_lo)?);
        let (stream, _) = listener.accept()?;
        let transport = TcpTransport::from_stream(stream);
        let mut lo = LabelOwner::new(engine, &model_lo, method, transport, 7)?;
        let ds = for_model(&model_lo, lo.meta.n_classes, seed, 256, 4096);
        let batch_size = lo.meta.batch;
        for req in 0..requests {
            let idx: Vec<usize> = (req * batch_size..(req + 1) * batch_size).collect();
            let batch = ds.batch(Split::Test, &idx, false);
            lo.eval_step(req as u64, &batch.y)?;
        }
        Ok(lo.transport.stats().bytes_recv)
    });

    // feature owner: the client device
    let engine = Rc::new(Engine::load(&dir)?);
    let transport = TcpTransport::connect(addr)?;
    let mut fo = FeatureOwner::new(engine, &model, method, transport, seed, 7)?;
    let ds = for_model(&model, fo.meta.n_classes, seed, 256, 4096);
    let batch_size = fo.meta.batch;

    let mut lat = Stats::new();
    let mut correct = 0.0f32;
    let mut n = 0usize;
    let t_all = std::time::Instant::now();
    for req in 0..requests {
        let idx: Vec<usize> = (req * batch_size..(req + 1) * batch_size).collect();
        let batch = ds.batch(Split::Test, &idx, false);
        let t0 = std::time::Instant::now();
        fo.eval_forward(req as u64, &batch.x)?;
        let (_, c) = fo.recv_eval_result()?;
        lat.push(t0.elapsed().as_secs_f64() * 1e3);
        correct += c;
        n += batch_size;
    }
    let total = t_all.elapsed().as_secs_f64();
    let server_bytes = server.join().unwrap()?;

    let s = fo.transport.stats();
    println!("serve_inference — {model} + {method}, {requests} requests x batch {batch_size}");
    println!(
        "  latency    : p/mean {:.2} ms, min {:.2} ms, max {:.2} ms (incl. bottom model on device)",
        lat.mean(), lat.min, lat.max
    );
    println!(
        "  throughput : {:.0} samples/s ({:.1} req/s)",
        n as f64 / total,
        requests as f64 / total
    );
    println!(
        "  accuracy   : {:.2}% on {} test samples",
        100.0 * correct as f64 / n as f64,
        n
    );
    println!(
        "  wire       : sent {:.1} KiB ({:.2}% of dense activations), recv {:.1} KiB",
        s.bytes_sent as f64 / 1024.0,
        fo.mean_fwd_pct().max(
            // eval_forward doesn't accumulate fwd_pct; derive from totals
            100.0 * s.bytes_sent as f64
                / (requests * batch_size * fo.meta.cut_dim * 4) as f64
        ),
        s.bytes_recv as f64 / 1024.0
    );
    assert_eq!(server_bytes, s.bytes_sent);
    Ok(())
}
