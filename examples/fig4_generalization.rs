//! Figure 4 reproduction: training loss curves (a) and generalization
//! error (b) for Topk vs RandTopk at several alphas, at the paper's
//! high-compression level.
//!
//! Generalization error = train-set accuracy - test-set accuracy, both
//! measured at the inference phase (deterministic top-k), per epoch.
//!
//! ```bash
//! cargo run --release --example fig4_generalization -- --task mlp --epochs 10
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::Trainer;
use splitfed::data::Split;
use splitfed::runtime::{default_artifacts_dir, Engine};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);
    let task = args.get_or("task", "mlp").to_string();
    let epochs: u32 = args.get_parse("epochs")?.unwrap_or(10);
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(4096);
    let lr: f32 = args.get_parse("lr")?.unwrap_or(0.05);

    let meta = engine.manifest.model(&task)?.clone();
    let k = meta.k_levels[0]; // highest compression (paper: 2.86% on CIFAR-100)

    let alphas = [0.0f32, 0.05, 0.1, 0.2, 0.3];
    let dir = std::path::Path::new("runs/fig4");
    std::fs::create_dir_all(dir)?;

    println!("Fig 4 — {task}, k = {k}: train loss + generalization error per alpha\n");
    let mut csv = String::from("alpha,epoch,train_loss,train_acc,test_acc,gen_error\n");
    let mut summary = Vec::new();
    for alpha in alphas {
        let method = if alpha == 0.0 {
            Method::Topk { k }
        } else {
            Method::RandTopk { k, alpha }
        };
        let mut cfg = ExperimentConfig::default();
        cfg.model = task.clone();
        cfg.method = method;
        cfg.epochs = epochs;
        cfg.n_train = n_train;
        cfg.n_test = n_train / 4;
        cfg.lr = lr;
        cfg.seed = 42;
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        let mut last = (0.0, 0.0, 0.0);
        for epoch in 0..epochs {
            let (train_loss, _) = trainer.train_epoch(epoch)?;
            // inference-phase accuracy on both splits (deterministic top-k)
            let (_, train_acc) = trainer.evaluate_split(Split::Train)?;
            let (_, test_acc) = trainer.evaluate_split(Split::Test)?;
            let gen_err = train_acc - test_acc;
            csv.push_str(&format!(
                "{alpha},{epoch},{train_loss:.6},{train_acc:.6},{test_acc:.6},{gen_err:.6}\n"
            ));
            last = (train_loss, train_acc, test_acc);
        }
        println!(
            "alpha={alpha:<5} final: train_loss={:.4} train_acc={:.4} test_acc={:.4} gen_err={:.4}",
            last.0,
            last.1,
            last.2,
            last.1 - last.2
        );
        summary.push((alpha, last));
    }
    std::fs::write(dir.join(format!("{task}.csv")), csv)?;

    // paper's claims: randtopk reaches lower train loss than topk (4a) and
    // smaller generalization error at matched train acc (4b)
    let topk = summary[0].1;
    if let Some((_, best)) = summary[1..]
        .iter()
        .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
    {
        println!(
            "\ntopk train_loss {:.4} vs best randtopk {:.4} — paper predicts randtopk lower",
            topk.0, best.0
        );
    }
    println!("wrote runs/fig4/{task}.csv");
    Ok(())
}
