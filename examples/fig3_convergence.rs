//! Figure 3 reproduction: convergence speed — accuracy vs epochs (top row)
//! and accuracy vs total communication (bottom row) for each method at a
//! fixed compression level.
//!
//! ```bash
//! cargo run --release --example fig3_convergence -- --task mlp --epochs 10
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::train;
use splitfed::runtime::{default_artifacts_dir, Engine};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);
    let task = args.get_or("task", "mlp").to_string();
    let epochs: u32 = args.get_parse("epochs")?.unwrap_or(10);
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(4096);
    let lr: f32 = args.get_parse("lr")?.unwrap_or(match task.as_str() {
        "textcnn" | "gru4rec" => 0.3,
        "convnet" | "convnet_l" => 0.1,
        _ => 0.05,
    });

    let meta = engine.manifest.model(&task)?.clone();
    // medium compression level (middle k)
    let k = meta.k_levels[meta.k_levels.len() / 2];
    let alpha = if task == "gru4rec" { 0.05 } else { 0.1 };

    let methods = vec![
        ("non-sparse", Method::None),
        ("randtopk", Method::RandTopk { k, alpha }),
        ("topk", Method::Topk { k }),
        ("sizered", Method::SizeReduction { k }),
        ("quant2bit", Method::Quant { bits: 2 }),
    ];

    let dir = std::path::Path::new("runs/fig3");
    std::fs::create_dir_all(dir)?;
    println!("Fig 3 — convergence on {task} (k = {k}, {epochs} epochs)\n");

    let mut curves = Vec::new();
    for (name, method) in methods {
        let mut cfg = ExperimentConfig::default();
        cfg.model = task.clone();
        cfg.method = method;
        cfg.epochs = epochs;
        cfg.n_train = n_train;
        cfg.n_test = n_train / 4;
        cfg.lr = lr;
        cfg.seed = 42;
        let ledger = train(engine.clone(), cfg, false)?;
        // normalize communication: vanilla one-epoch comm = 1.0 (paper's x axis)
        eprintln!(
            "  {name}: final acc {:.2}%, total comm {:.2} MiB",
            100.0 * ledger.final_metric(),
            ledger.total_comm_bytes() as f64 / 1048576.0
        );
        ledger.save(dir, &format!("{task}_{name}"))?;
        curves.push((name, ledger));
    }

    // the vanilla per-epoch communication is the unit of the bottom row
    let vanilla_epoch_bytes = curves
        .iter()
        .find(|(n, _)| *n == "non-sparse")
        .map(|(_, l)| l.total_comm_bytes() as f64 / epochs as f64)
        .unwrap_or(1.0);

    println!("\naccuracy vs epochs:");
    print!("{:<7}", "epoch");
    for (name, _) in &curves {
        print!("{name:>12}");
    }
    println!();
    for e in 0..epochs as usize {
        print!("{:<7}", e);
        for (_, l) in &curves {
            print!("{:>12.4}", l.epochs[e].test_metric);
        }
        println!();
    }

    println!("\naccuracy vs communication (unit = vanilla one-epoch traffic):");
    print!("{:<7}", "epoch");
    for (name, _) in &curves {
        print!("{name:>16}");
    }
    println!();
    for e in 0..epochs as usize {
        print!("{:<7}", e);
        for (_, l) in &curves {
            print!(
                "  {:>6.3}u/{:>6.4}",
                l.epochs[e].comm_bytes as f64 / vanilla_epoch_bytes,
                l.epochs[e].test_metric
            );
        }
        println!();
    }
    println!("\nper-method ledgers in runs/fig3/{task}_<method>.json|csv");
    Ok(())
}
