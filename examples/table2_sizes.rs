//! Table 2 reproduction: analytic compressed-size formulas vs *measured*
//! wire bytes of the real codecs, for every (d, k/b) the paper evaluates.
//!
//! Codecs come from the `compress::codec_for` registry — the exact
//! objects the coordinator parties encode with in production — so this
//! cross-check covers the deployed code path, not a parallel
//! reimplementation.
//!
//! ```bash
//! cargo run --release --example table2_sizes
//! ```

use anyhow::Result;
use splitfed::compress::{
    codec_for, codec_for_layout, Batch, DenseBatch, IndexLayout, Pass, QuantBatch, SparseBatch,
};
use splitfed::config::Method;
use splitfed::util::Rng;

fn random_sparse(rng: &mut Rng, rows: usize, dim: usize, k: usize, implicit: bool) -> SparseBatch {
    let mut values = Vec::new();
    let mut indices = Vec::new();
    for _ in 0..rows {
        let sel: Vec<i32> = if implicit {
            (0..k as i32).collect()
        } else {
            let mut all: Vec<i32> = (0..dim as i32).collect();
            rng.shuffle(&mut all);
            let mut s = all[..k].to_vec();
            s.sort_unstable();
            s
        };
        for &i in &sel {
            indices.push(i);
            values.push(rng.normal());
        }
    }
    SparseBatch { rows, dim, k, values, indices }
}

fn main() -> Result<()> {
    let rows = 32;
    let mut rng = Rng::new(42);

    println!("Table 2 — compressed size (fraction of dense), analytic vs measured");
    println!("(measured = registry codec wire bytes / dense bytes; rows = batch {rows})\n");
    println!(
        "{:<24} {:>6} {:>4} | {:>9} {:>9} | {:>9} {:>9}",
        "method", "d", "k/b", "fwd(ana)", "fwd(meas)", "bwd(ana)", "bwd(meas)"
    );

    // the paper's four task geometries
    let geoms: &[(usize, &[usize])] = &[
        (128, &[3, 6, 13]),
        (300, &[2, 4, 9]),
        (600, &[2, 4, 9, 14]),
        (1280, &[2, 4, 9]),
    ];

    for &(d, ks) in geoms {
        let dense_bytes = (rows * d * 4) as f64;
        for &k in ks {
            // top-k / randtopk (identical wire form)
            let codec = codec_for(Method::Topk { k }, d)?;
            let batch = Batch::Sparse(random_sparse(&mut rng, rows, d, k, false));
            let fwd = codec.encode(&batch, Pass::Forward)?.wire_bytes() as f64 / dense_bytes;
            let bwd = codec.encode(&batch, Pass::Backward)?.wire_bytes() as f64 / dense_bytes;
            let m = codec.size_model();
            println!(
                "{:<24} {:>6} {:>4} | {:>8.3}% {:>8.3}% | {:>8.3}% {:>8.3}%",
                "top-k / randtopk",
                d,
                k,
                100.0 * m.forward_fraction(),
                100.0 * fwd,
                100.0 * m.backward_fraction(),
                100.0 * bwd
            );
            // top-k with LEB128-delta indices (opt-in layout; analytic
            // column is the estimate — the wire size is input-dependent)
            let codec = codec_for_layout(Method::Topk { k }, d, IndexLayout::Leb128Delta)?;
            let fwd = codec.encode(&batch, Pass::Forward)?.wire_bytes() as f64 / dense_bytes;
            let bwd = codec.encode(&batch, Pass::Backward)?.wire_bytes() as f64 / dense_bytes;
            let m = codec.size_model();
            println!(
                "{:<24} {:>6} {:>4} | {:>8.3}% {:>8.3}% | {:>8.3}% {:>8.3}%",
                "top-k (leb128 idx)",
                d,
                k,
                100.0 * m.forward_fraction(),
                100.0 * fwd,
                100.0 * m.backward_fraction(),
                100.0 * bwd
            );
            // size reduction
            let codec = codec_for(Method::SizeReduction { k }, d)?;
            let sr = Batch::Sparse(random_sparse(&mut rng, rows, d, k, true));
            let fwd = codec.encode(&sr, Pass::Forward)?.wire_bytes() as f64 / dense_bytes;
            let m = codec.size_model();
            println!(
                "{:<24} {:>6} {:>4} | {:>8.3}% {:>8.3}% | {:>8.3}% {:>8.3}%",
                "size reduction",
                d,
                k,
                100.0 * m.forward_fraction(),
                100.0 * fwd,
                100.0 * m.backward_fraction(),
                100.0 * fwd
            );
        }
        for bits in [1u8, 2, 4] {
            let codec = codec_for(Method::Quant { bits }, d)?;
            let levels = (1u64 << bits) as f32;
            let dense = DenseBatch::new(rows, d, (0..rows * d).map(|_| rng.normal()).collect());
            let batch = Batch::Quant(QuantBatch {
                rows,
                dim: d,
                codes: dense
                    .data
                    .iter()
                    .map(|v| ((v + 3.0) / 6.0 * levels).floor().clamp(0.0, levels - 1.0))
                    .collect(),
                o_min: vec![-3.0; rows],
                o_max: vec![3.0; rows],
            });
            let fwd = codec.encode(&batch, Pass::Forward)?.wire_bytes() as f64 / dense_bytes;
            let m = codec.size_model();
            println!(
                "{:<24} {:>6} {:>4} | {:>8.3}% {:>8.3}% | {:>8.3}% {:>9}",
                "quantization",
                d,
                bits,
                100.0 * m.forward_fraction(),
                100.0 * fwd,
                100.0 * m.backward_fraction(),
                "dense"
            );
        }
        println!();
    }

    println!("note: measured fwd for top-k includes bit-padding to byte boundaries;");
    println!("quantization carries an 8-byte per-row (min,max) header — visible at small d.");
    println!("top-k (leb128 idx) wins where gaps (~d/k) fit one varint byte but the dim");
    println!("needs >8 fixed bits (e.g. d=600,k=14); it loses where gaps run wide (d/k>127).");
    println!("\n§1 motivating example: ResNet-20 cut 32x32x32, batch 32, fwd+bwd f32 =");
    let bytes = 2usize * 4 * 32 * 32 * 32 * 32;
    println!("  {} bytes = {} MiB per iteration (paper: 8 MiB)", bytes, bytes / 1048576);
    Ok(())
}
