//! Appendix B reproduction (Fig 6/7): input-inversion attack on the cut
//! layer. A decoder network is trained to reconstruct X from the
//! (sparsified) bottom-model output; the reconstruction error orders the
//! methods' input privacy: RandTopk >= Topk >> non-sparse.
//!
//! ```bash
//! cargo run --release --example fig7_inversion -- --epochs 4 --dec-epochs 6
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::Trainer;
use splitfed::data::{Dataset, EpochIter, Split};
use splitfed::runtime::{default_artifacts_dir, Engine, HostTensor};
use xla::Literal;

struct Decoder {
    engine: Arc<Engine>,
    params: Vec<Literal>,
    moms: Vec<Literal>,
    k: usize,
}

impl Decoder {
    fn new(engine: Arc<Engine>, k: usize, seed: i32) -> Result<Self> {
        let outs = engine.exec(
            "convnet/decoder/init",
            &[HostTensor::scalar_i32(seed).to_literal()?],
        )?;
        let meta = engine.manifest.model("convnet")?;
        let shapes = meta.decoder_shapes.clone().unwrap();
        let moms = engine.zero_momentum(&shapes)?;
        Ok(Decoder { engine, params: outs, moms, k })
    }

    fn train_step(&mut self, values: &Literal, indices: &Literal, x: &Literal, lr: f32) -> Result<f32> {
        let lr_l = HostTensor::vec1_f32(&[lr]).to_literal()?;
        let mut borrowed: Vec<&Literal> = self.params.iter().chain(self.moms.iter()).collect();
        borrowed.push(values);
        borrowed.push(indices);
        borrowed.push(x);
        borrowed.push(&lr_l);
        let key = format!("convnet/decoder_k{}/train", self.k);
        let mut outs = self.engine.exec(&key, &borrowed)?;
        let loss = HostTensor::from_literal(outs.last().unwrap())?.scalar()?;
        outs.pop();
        let nd = self.params.len();
        let moms = outs.split_off(nd);
        self.params = outs;
        self.moms = moms;
        Ok(loss)
    }

    fn eval(&self, values: &Literal, indices: &Literal, x: &Literal) -> Result<f32> {
        let mut borrowed: Vec<&Literal> = self.params.iter().collect();
        borrowed.push(values);
        borrowed.push(indices);
        borrowed.push(x);
        let key = format!("convnet/decoder_k{}/eval", self.k);
        let outs = self.engine.exec(&key, &borrowed)?;
        HostTensor::from_literal(&outs[0])?.scalar().map_err(Into::into)
    }
}

/// Produce (values, indices) literals for the decoder from a batch,
/// matching the attack surface of each method.
fn activations(
    trainer: &Trainer,
    x: &HostTensor,
    k: usize,
    dense: bool,
) -> Result<(Literal, Literal)> {
    let meta = &trainer.fo.meta;
    if dense {
        let o = trainer.fo.dense_activations(x)?;
        let b = meta.batch;
        let d = meta.cut_dim;
        let idx: Vec<i32> = (0..b).flat_map(|_| 0..d as i32).collect();
        Ok((
            o.to_literal()?,
            HostTensor::i32(idx, &[b, d]).to_literal()?,
        ))
    } else {
        // deterministic top-k selection, the inference-phase view
        let idx = trainer.fo.selection_indices(x, k)?;
        let o = trainer.fo.dense_activations(x)?;
        let b = meta.batch;
        let d = meta.cut_dim;
        let of = o.as_f32()?;
        let mut vals = Vec::with_capacity(b * k);
        for r in 0..b {
            for j in 0..k {
                vals.push(of[r * d + idx[r * k + j] as usize]);
            }
        }
        Ok((
            HostTensor::f32(vals, &[b, k]).to_literal()?,
            HostTensor::i32(idx, &[b, k]).to_literal()?,
        ))
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);
    let epochs: u32 = args.get_parse("epochs")?.unwrap_or(4);
    let dec_epochs: u32 = args.get_parse("dec-epochs")?.unwrap_or(6);
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(1024);

    let meta = engine.manifest.model("convnet")?.clone();
    let k = meta.k_levels[0]; // paper: 3 of 128 preserved (2.86%)

    println!("Fig 7 — inversion attack on convnet (k = {k}, train {epochs} ep, decoder {dec_epochs} ep)\n");
    let dir = std::path::Path::new("runs/fig7");
    std::fs::create_dir_all(dir)?;

    let configs: Vec<(&str, Method, bool)> = vec![
        ("non-sparse", Method::None, true),
        ("topk", Method::Topk { k }, false),
        ("randtopk_0.05", Method::RandTopk { k, alpha: 0.05 }, false),
        ("randtopk_0.1", Method::RandTopk { k, alpha: 0.1 }, false),
        ("randtopk_0.2", Method::RandTopk { k, alpha: 0.2 }, false),
    ];

    let mut csv = String::from("method,recon_error\n");
    for (name, method, dense) in configs {
        // 1) train the split model with this method
        let mut cfg = ExperimentConfig::default();
        cfg.model = "convnet".into();
        cfg.method = method;
        cfg.epochs = epochs;
        cfg.n_train = n_train;
        cfg.n_test = 256;
        cfg.lr = 0.1;
        cfg.seed = 42;
        cfg.eval_every = epochs;
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        trainer.run()?;

        // 2) train the attack decoder on train-set activations
        let dec_k = if dense { meta.cut_dim } else { k };
        let mut dec = Decoder::new(engine.clone(), dec_k, 7)?;
        for ep in 0..dec_epochs {
            let mut loss_sum = 0.0f32;
            let mut nb = 0;
            for indices in EpochIter::new(n_train, meta.batch, 9, ep) {
                let batch = trainer.dataset.batch(Split::Train, &indices, false);
                let (v, i) = activations(&trainer, &batch.x, k, dense)?;
                let x_lit = batch.x.to_literal()?;
                loss_sum += dec.train_step(&v, &i, &x_lit, 0.02)?;
                nb += 1;
            }
            eprintln!("  {name} decoder epoch {ep}: mse {:.4}", loss_sum / nb as f32);
        }

        // 3) reconstruction error on the test set
        let mut err_sum = 0.0f32;
        let mut n = 0usize;
        for indices in EpochIter::sequential(256, meta.batch) {
            let batch = trainer.dataset.batch(Split::Test, &indices, false);
            let (v, i) = activations(&trainer, &batch.x, k, dense)?;
            let x_lit = batch.x.to_literal()?;
            err_sum += dec.eval(&v, &i, &x_lit)?;
            n += indices.len();
        }
        let err = err_sum / n as f32;
        println!("{name:<16} reconstruction error (MSE) = {err:.4}");
        csv.push_str(&format!("{name},{err}\n"));
    }
    std::fs::write(dir.join("convnet.csv"), csv)?;
    println!("\npaper's claim: non-sparse << topk <= randtopk (larger = more private)");
    println!("wrote runs/fig7/convnet.csv");
    Ok(())
}
