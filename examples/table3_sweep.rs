//! Table 3 (and appendix Tables 5-8) reproduction: accuracy vs compressed
//! size for every method at matched compression levels.
//!
//! ```bash
//! cargo run --release --example table3_sweep -- --task convnet --quick
//! cargo run --release --example table3_sweep -- --task mlp --seeds 3
//! cargo run --release --example table3_sweep -- --describe   # Table 4
//! ```
//!
//! Methods per level follow the paper: RandTopk / Topk / SizeReduction at
//! matched k; Quantization only at the levels where 1/2/4-bit sizes fit;
//! L1 with a lambda grid (its size is emergent, reported as measured).

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::compress::{codec_for, codec_for_layout, Batch, IndexLayout, Pass, SparseBatch};
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::train;
use splitfed::metrics::mean_std;
use splitfed::runtime::{default_artifacts_dir, Engine};

struct Row {
    level: String,
    method: String,
    accs: Vec<f64>,
    sizes: Vec<f64>,
    /// Registry-predicted forward size (%), None when emergent (L1).
    ana: Option<f64>,
}

fn level_name(model: &str, idx: usize, n_levels: usize) -> String {
    let names: &[&str] = if n_levels == 4 {
        &["High+", "High", "Medium", "Low"]
    } else {
        &["High", "Medium", "Low"]
    };
    let _ = model;
    names[idx].to_string()
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);

    if args.has_flag("describe") {
        // Table 4: dataset details
        println!("Table 4 — dataset details (synthetic analogs, DESIGN.md §2)");
        println!("{:<12} {:>9} {:>18}", "task", "#classes", "dim of last layer");
        for (name, m) in &engine.manifest.models {
            println!("{:<12} {:>9} {:>18}", name, m.n_classes, m.cut_dim);
        }
        return Ok(());
    }

    let task = args.get_or("task", "mlp").to_string();
    let seeds: u64 = args.get_parse("seeds")?.unwrap_or(1);
    let quick = args.has_flag("quick");
    let epochs: u32 = args
        .get_parse("epochs")?
        .unwrap_or(if quick { 3 } else { 15 });
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(if quick { 1024 } else { 8192 });
    let alpha: f32 = args.get_parse("alpha")?.unwrap_or(if task == "gru4rec" { 0.05 } else { 0.1 });
    let lr: f32 = args.get_parse("lr")?.unwrap_or(match task.as_str() {
        "textcnn" | "gru4rec" => 0.3,
        "convnet" | "convnet_l" => 0.1,
        _ => 0.05,
    });

    let meta = engine.manifest.model(&task)?.clone();
    let mut rows: Vec<Row> = Vec::new();

    let cut_dim = meta.cut_dim;
    let mut run_one = |method: Method, level: &str, rows: &mut Vec<Row>| -> Result<()> {
        // the analytic prediction comes from the SAME registry codec the
        // trainer's parties encode with — the Table 2/3 cross-check covers
        // the production code path
        let ana = codec_for(method, cut_dim).ok().and_then(|c| {
            c.expected_wire_bytes(1, Pass::Forward)
                .map(|_| 100.0 * c.size_model().forward_fraction())
        });
        let mut accs = Vec::new();
        let mut sizes = Vec::new();
        for seed in 0..seeds {
            let mut cfg = ExperimentConfig::default();
            cfg.model = task.clone();
            cfg.method = method;
            cfg.epochs = epochs;
            cfg.n_train = n_train;
            cfg.n_test = n_train / 4;
            cfg.lr = lr;
            cfg.seed = 100 + seed;
            cfg.eval_every = epochs; // final eval only
            let ledger = train(engine.clone(), cfg, false)?;
            accs.push(100.0 * ledger.final_metric());
            sizes.push(ledger.fwd_compressed_pct);
        }
        let (am, asd) = mean_std(&accs);
        let (sm, _) = mean_std(&sizes);
        let ana_str = ana.map_or("-".into(), |a| format!("{a:.2}%"));
        eprintln!("  [{level:<7}] {method}: acc {am:.2} ({asd:.2}) size {sm:.2}% (analytic {ana_str})");
        rows.push(Row {
            level: level.into(),
            method: method.to_string(),
            accs,
            sizes,
            ana,
        });
        Ok(())
    };

    // vanilla baseline
    run_one(Method::None, "none", &mut rows)?;

    let n_levels = meta.k_levels.len();
    for (i, &k) in meta.k_levels.iter().enumerate() {
        let level = level_name(&task, i, n_levels);
        run_one(Method::RandTopk { k, alpha }, &level, &mut rows)?;
        run_one(Method::Topk { k }, &level, &mut rows)?;
        run_one(Method::SizeReduction { k }, &level, &mut rows)?;
    }
    // quantization at its feasible sizes (1/2/4 bit = 3.13/6.25/12.5%)
    if !args.has_flag("no-quant") {
        for bits in [1usize, 2, 4] {
            run_one(Method::Quant { bits: bits as u8 }, &format!("q{bits}bit"), &mut rows)?;
        }
    }
    // L1 lambda grid (compressed size emergent)
    if !args.has_flag("no-l1") {
        for lambda in [0.001f32, 0.0005, 0.0002] {
            run_one(Method::L1 { lambda, eps: 1e-4 }, &format!("l1 {lambda}"), &mut rows)?;
        }
    }

    println!("\nTable 3 — {task}: accuracy (std) / compressed size (%), {seeds} seed(s), {epochs} epochs");
    println!(
        "{:<9} {:<28} {:>16} {:>12} {:>8}",
        "level", "method", "accuracy (std)", "size %", "ana %"
    );
    for r in &rows {
        let (am, asd) = mean_std(&r.accs);
        let (sm, ssd) = mean_std(&r.sizes);
        let size = if ssd > 0.005 {
            format!("{sm:.2} ({ssd:.2})")
        } else {
            format!("{sm:.2}")
        };
        let ana = r.ana.map_or("-".to_string(), |a| format!("{a:.2}"));
        println!(
            "{:<9} {:<28} {:>9.2} ({:>4.2}) {:>12} {:>8}",
            r.level, r.method, am, asd, size, ana
        );
    }

    // persist for downstream figure drivers
    let dir = std::path::Path::new("runs/table3");
    std::fs::create_dir_all(dir)?;
    let mut csv = String::from("level,method,acc_mean,acc_std,size_mean,size_analytic\n");
    for r in &rows {
        let (am, asd) = mean_std(&r.accs);
        let (sm, _) = mean_std(&r.sizes);
        let ana = r.ana.map_or(String::new(), |a| format!("{a}"));
        csv.push_str(&format!("{},{},{am},{asd},{sm},{ana}\n", r.level, r.method));
    }
    std::fs::write(dir.join(format!("{task}.csv")), csv)?;
    println!("\nwrote runs/table3/{task}.csv");

    // Index-layout comparison for the sweep's top-k levels: measured
    // forward wire bytes of the bitpack vs LEB128-delta layouts on the
    // SAME selection pattern the codec would ship (sizes are measured by
    // encoding real batches, not asserted from the analytic model).
    println!("\nindex layout (top-k forward, % of dense, batch {}):", meta.batch);
    println!("{:<6} {:>14} {:>14} {:>10}", "k", "bitpack %", "leb128 %", "leb/bp");
    let mut layout_csv = String::from("k,bitpack_pct,leb128_pct\n");
    let mut rng = splitfed::util::Rng::new(7);
    for &k in meta.k_levels.iter() {
        let rows_n = meta.batch;
        let mut values = Vec::new();
        let mut indices = Vec::new();
        for _ in 0..rows_n {
            let mut all: Vec<i32> = (0..cut_dim as i32).collect();
            rng.shuffle(&mut all);
            let mut sel = all[..k].to_vec();
            sel.sort_unstable();
            for &i in &sel {
                indices.push(i);
                values.push(rng.normal());
            }
        }
        let batch =
            Batch::Sparse(SparseBatch { rows: rows_n, dim: cut_dim, k, values, indices });
        let dense = (rows_n * cut_dim * 4) as f64;
        let bp = codec_for(Method::Topk { k }, cut_dim)?
            .encode(&batch, Pass::Forward)?
            .wire_bytes() as f64;
        let leb = codec_for_layout(Method::Topk { k }, cut_dim, IndexLayout::Leb128Delta)?
            .encode(&batch, Pass::Forward)?
            .wire_bytes() as f64;
        println!(
            "{:<6} {:>13.3}% {:>13.3}% {:>10.3}",
            k,
            100.0 * bp / dense,
            100.0 * leb / dense,
            leb / bp
        );
        layout_csv.push_str(&format!("{k},{},{}\n", 100.0 * bp / dense, 100.0 * leb / dense));
    }
    std::fs::write(dir.join(format!("{task}_index_layout.csv")), layout_csv)?;
    println!("wrote runs/table3/{task}_index_layout.csv");
    Ok(())
}
