//! Table 3 (and appendix Tables 5-8) reproduction: accuracy vs compressed
//! size for every method at matched compression levels.
//!
//! ```bash
//! cargo run --release --example table3_sweep -- --task convnet --quick
//! cargo run --release --example table3_sweep -- --task mlp --seeds 3
//! cargo run --release --example table3_sweep -- --describe   # Table 4
//! ```
//!
//! Methods per level follow the paper: RandTopk / Topk / SizeReduction at
//! matched k; Quantization only at the levels where 1/2/4-bit sizes fit;
//! L1 with a lambda grid (its size is emergent, reported as measured).

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::compress::{codec_for, Pass};
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::train;
use splitfed::metrics::mean_std;
use splitfed::runtime::{default_artifacts_dir, Engine};

struct Row {
    level: String,
    method: String,
    accs: Vec<f64>,
    sizes: Vec<f64>,
    /// Registry-predicted forward size (%), None when emergent (L1).
    ana: Option<f64>,
}

fn level_name(model: &str, idx: usize, n_levels: usize) -> String {
    let names: &[&str] = if n_levels == 4 {
        &["High+", "High", "Medium", "Low"]
    } else {
        &["High", "Medium", "Low"]
    };
    let _ = model;
    names[idx].to_string()
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);

    if args.has_flag("describe") {
        // Table 4: dataset details
        println!("Table 4 — dataset details (synthetic analogs, DESIGN.md §2)");
        println!("{:<12} {:>9} {:>18}", "task", "#classes", "dim of last layer");
        for (name, m) in &engine.manifest.models {
            println!("{:<12} {:>9} {:>18}", name, m.n_classes, m.cut_dim);
        }
        return Ok(());
    }

    let task = args.get_or("task", "mlp").to_string();
    let seeds: u64 = args.get_parse("seeds")?.unwrap_or(1);
    let quick = args.has_flag("quick");
    let epochs: u32 = args
        .get_parse("epochs")?
        .unwrap_or(if quick { 3 } else { 15 });
    let n_train: usize = args.get_parse("n_train")?.unwrap_or(if quick { 1024 } else { 8192 });
    let alpha: f32 = args.get_parse("alpha")?.unwrap_or(if task == "gru4rec" { 0.05 } else { 0.1 });
    let lr: f32 = args.get_parse("lr")?.unwrap_or(match task.as_str() {
        "textcnn" | "gru4rec" => 0.3,
        "convnet" | "convnet_l" => 0.1,
        _ => 0.05,
    });

    let meta = engine.manifest.model(&task)?.clone();
    let mut rows: Vec<Row> = Vec::new();

    let cut_dim = meta.cut_dim;
    let mut run_one = |method: Method, level: &str, rows: &mut Vec<Row>| -> Result<()> {
        // the analytic prediction comes from the SAME registry codec the
        // trainer's parties encode with — the Table 2/3 cross-check covers
        // the production code path
        let ana = codec_for(method, cut_dim).ok().and_then(|c| {
            c.expected_wire_bytes(1, Pass::Forward)
                .map(|_| 100.0 * c.size_model().forward_fraction())
        });
        let mut accs = Vec::new();
        let mut sizes = Vec::new();
        for seed in 0..seeds {
            let mut cfg = ExperimentConfig::default();
            cfg.model = task.clone();
            cfg.method = method;
            cfg.epochs = epochs;
            cfg.n_train = n_train;
            cfg.n_test = n_train / 4;
            cfg.lr = lr;
            cfg.seed = 100 + seed;
            cfg.eval_every = epochs; // final eval only
            let ledger = train(engine.clone(), cfg, false)?;
            accs.push(100.0 * ledger.final_metric());
            sizes.push(ledger.fwd_compressed_pct);
        }
        let (am, asd) = mean_std(&accs);
        let (sm, _) = mean_std(&sizes);
        let ana_str = ana.map_or("-".into(), |a| format!("{a:.2}%"));
        eprintln!("  [{level:<7}] {method}: acc {am:.2} ({asd:.2}) size {sm:.2}% (analytic {ana_str})");
        rows.push(Row {
            level: level.into(),
            method: method.to_string(),
            accs,
            sizes,
            ana,
        });
        Ok(())
    };

    // vanilla baseline
    run_one(Method::None, "none", &mut rows)?;

    let n_levels = meta.k_levels.len();
    for (i, &k) in meta.k_levels.iter().enumerate() {
        let level = level_name(&task, i, n_levels);
        run_one(Method::RandTopk { k, alpha }, &level, &mut rows)?;
        run_one(Method::Topk { k }, &level, &mut rows)?;
        run_one(Method::SizeReduction { k }, &level, &mut rows)?;
    }
    // quantization at its feasible sizes (1/2/4 bit = 3.13/6.25/12.5%)
    if !args.has_flag("no-quant") {
        for bits in [1usize, 2, 4] {
            run_one(Method::Quant { bits: bits as u8 }, &format!("q{bits}bit"), &mut rows)?;
        }
    }
    // L1 lambda grid (compressed size emergent)
    if !args.has_flag("no-l1") {
        for lambda in [0.001f32, 0.0005, 0.0002] {
            run_one(Method::L1 { lambda, eps: 1e-4 }, &format!("l1 {lambda}"), &mut rows)?;
        }
    }

    println!("\nTable 3 — {task}: accuracy (std) / compressed size (%), {seeds} seed(s), {epochs} epochs");
    println!(
        "{:<9} {:<28} {:>16} {:>12} {:>8}",
        "level", "method", "accuracy (std)", "size %", "ana %"
    );
    for r in &rows {
        let (am, asd) = mean_std(&r.accs);
        let (sm, ssd) = mean_std(&r.sizes);
        let size = if ssd > 0.005 {
            format!("{sm:.2} ({ssd:.2})")
        } else {
            format!("{sm:.2}")
        };
        let ana = r.ana.map_or("-".to_string(), |a| format!("{a:.2}"));
        println!(
            "{:<9} {:<28} {:>9.2} ({:>4.2}) {:>12} {:>8}",
            r.level, r.method, am, asd, size, ana
        );
    }

    // persist for downstream figure drivers
    let dir = std::path::Path::new("runs/table3");
    std::fs::create_dir_all(dir)?;
    let mut csv = String::from("level,method,acc_mean,acc_std,size_mean,size_analytic\n");
    for r in &rows {
        let (am, asd) = mean_std(&r.accs);
        let (sm, _) = mean_std(&r.sizes);
        let ana = r.ana.map_or(String::new(), |a| format!("{a}"));
        csv.push_str(&format!("{},{},{am},{asd},{sm},{ana}\n", r.level, r.method));
    }
    std::fs::write(dir.join(format!("{task}.csv")), csv)?;
    println!("\nwrote runs/table3/{task}.csv");
    Ok(())
}
