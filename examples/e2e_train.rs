//! End-to-end validation run: train the convnet split model with RandTopk
//! for several hundred steps on SynthVision-100, logging the loss curve
//! and the exact communication ledger. The run recorded in EXPERIMENTS.md
//! §E2E comes from this binary.
//!
//! ```bash
//! cargo run --release --example e2e_train -- --epochs 8 --n_train 4096
//! ```

use std::sync::Arc;

use anyhow::Result;
use splitfed::cli::Args;
use splitfed::config::{ExperimentConfig, Method};
use splitfed::coordinator::Trainer;
use splitfed::runtime::{default_artifacts_dir, Engine};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let engine = Arc::new(Engine::load(default_artifacts_dir())?);

    let mut cfg = ExperimentConfig::default();
    cfg.model = args.get_or("model", "convnet").to_string();
    cfg.method = splitfed::config::Method::parse(
        args.get_or("method", "randtopk:k=6,alpha=0.1"),
    )?;
    cfg.epochs = args.get_parse("epochs")?.unwrap_or(8);
    cfg.n_train = args.get_parse("n_train")?.unwrap_or(4096);
    cfg.n_test = args.get_parse("n_test")?.unwrap_or(1024);
    cfg.lr = args.get_parse("lr")?.unwrap_or(0.1);
    cfg.seed = args.get_parse("seed")?.unwrap_or(42);

    let steps_per_epoch = cfg.n_train / 32;
    println!(
        "e2e: {} + {} | {} epochs x {} steps | link {} Mbit/s, {} ms\n",
        cfg.model, cfg.method, cfg.epochs, steps_per_epoch, cfg.bandwidth_mbps, cfg.latency_ms
    );

    let mut trainer = Trainer::new(engine.clone(), cfg)?;
    trainer.verbose = true;
    let ledger = trainer.run()?;

    println!("\nloss curve (train):");
    for e in &ledger.epochs {
        let bar_len = ((e.train_loss / ledger.epochs[0].train_loss.max(1e-9)) * 50.0) as usize;
        println!(
            "  epoch {:>2}  loss {:>7.4}  acc {:>6.3}  {}",
            e.epoch,
            e.train_loss,
            e.test_metric,
            "#".repeat(bar_len.min(60))
        );
    }

    let stats = engine.stats();
    println!("\nsummary:");
    println!("  total steps          : {}", ledger.epochs.len() * steps_per_epoch);
    println!("  final test accuracy  : {:.2}%", 100.0 * ledger.final_metric());
    println!(
        "  total communication  : {:.2} MiB ({:.2}% fwd / {:.2}% bwd of dense)",
        ledger.total_comm_bytes() as f64 / 1048576.0,
        ledger.fwd_compressed_pct,
        ledger.bwd_compressed_pct
    );
    println!(
        "  simulated link time  : {:.2} s",
        ledger.epochs.last().map(|e| e.sim_link_secs).unwrap_or(0.0)
    );
    println!(
        "  PJRT executions      : {} ({:.1} ms mean)",
        stats.executions,
        1e3 * stats.exec_secs / stats.executions.max(1) as f64
    );

    let dir = std::path::Path::new("runs/e2e");
    std::fs::create_dir_all(dir)?;
    let path = ledger.save(dir, "e2e_train")?;
    println!("  ledger               : {}", path.display());
    let _ = Method::None; // keep import used under all feature sets
    Ok(())
}
